//! [`SearchSpace`]: the knob cross-product, genotype encoding, legality
//! filtering, mutation, and the configuration-distance metric the
//! diversity-aware explorer uses.

use super::config::ScheduleConfig;
use crate::util::Rng;
use crate::workload::{OpWorkload, Workload};

/// A schedule encoded as per-knob value *indices* — the representation the
/// explorers mutate (AutoTVM's "knob" view of a config).
pub type Genotype = Vec<u8>;

/// One tunable dimension.
#[derive(Debug, Clone)]
pub struct Knob {
    /// Knob name (matches the `ScheduleConfig` field).
    pub name: &'static str,
    /// The values a genotype index selects among.
    pub values: Vec<usize>,
}

/// Options controlling which dimensions are searched.
#[derive(Debug, Clone, Copy)]
pub struct SpaceOptions {
    /// Include the §3.1–3.3 optimization flags as searchable knobs. When
    /// false (the paper's §4.3 setting: "the search space of the original
    /// AutoTVM"), the flags are pinned to `pinned_flags`.
    pub search_opt_flags: bool,
    /// Pinned `[dup_aware, reg_packing, nhwcnc_layout]` values used when
    /// the flags are not searched.
    pub pinned_flags: [bool; 3],
}

impl Default for SpaceOptions {
    fn default() -> Self {
        Self { search_opt_flags: true, pinned_flags: [true, true, true] }
    }
}

impl SpaceOptions {
    /// The original-AutoTVM space of §4.3 (tiling knobs only, all
    /// optimizations on).
    pub fn autotvm_original() -> Self {
        Self { search_opt_flags: false, pinned_flags: [true, true, true] }
    }

    /// Baseline space: tiling knobs only, all optimizations off.
    pub fn baseline() -> Self {
        Self { search_opt_flags: false, pinned_flags: [false, false, false] }
    }
}

/// The search space for one workload (any operator).
#[derive(Debug, Clone)]
pub struct SearchSpace {
    knobs: Vec<Knob>,
    opts: SpaceOptions,
    gemm: (usize, usize, usize),
    wl: OpWorkload,
}

const POW2: [usize; 4] = [1, 2, 4, 8];

impl SearchSpace {
    /// The knob space for one workload; legality is judged on the
    /// workload's [`Workload::legality_gemm`] view (a conv's per-group
    /// GEMM with N/K padded to the MMA atom; a matmul's raw M/N/K).
    pub fn for_workload(wl: impl Into<OpWorkload>, opts: SpaceOptions) -> Self {
        let wl = wl.into();
        let mut knobs = vec![
            Knob { name: "blk_row_warps", values: POW2.to_vec() },
            Knob { name: "blk_col_warps", values: POW2.to_vec() },
            Knob { name: "warp_row_tiles", values: POW2.to_vec() },
            Knob { name: "warp_col_tiles", values: POW2.to_vec() },
            Knob { name: "chunk", values: POW2.to_vec() },
            Knob { name: "reorder_inner", values: vec![0, 1] },
        ];
        if opts.search_opt_flags {
            knobs.push(Knob { name: "dup_aware", values: vec![0, 1] });
            knobs.push(Knob { name: "reg_packing", values: vec![0, 1] });
            knobs.push(Knob { name: "nhwcnc_layout", values: vec![0, 1] });
        }
        // legality is judged on the operator's own view: a conv's
        // *per-group* GEMM with N and K padded to the MMA atom (K-group
        // alignment per group — a depthwise conv tiles its one padded
        // 8x32 atom, not the raw (1, 9) GEMM), a matmul's raw (M, N, K)
        let gemm = wl.legality_gemm();
        Self { knobs, opts, gemm, wl }
    }

    /// The tunable dimensions, in genotype order.
    pub fn knobs(&self) -> &[Knob] {
        &self.knobs
    }

    /// The workload this space was built for.
    pub fn workload(&self) -> &OpWorkload {
        &self.wl
    }

    /// Number of knobs (== genotype length).
    pub fn n_knobs(&self) -> usize {
        self.knobs.len()
    }

    /// Cross-product cardinality (before legality filtering).
    pub fn cardinality(&self) -> usize {
        self.knobs.iter().map(|k| k.values.len()).product()
    }

    /// Decode a genotype into a concrete schedule.
    pub fn decode(&self, g: &Genotype) -> ScheduleConfig {
        debug_assert_eq!(g.len(), self.knobs.len());
        let v = |i: usize| self.knobs[i].values[g[i] as usize];
        let flags = if self.opts.search_opt_flags {
            [v(6) == 1, v(7) == 1, v(8) == 1]
        } else {
            self.opts.pinned_flags
        };
        ScheduleConfig {
            blk_row_warps: v(0),
            blk_col_warps: v(1),
            warp_row_tiles: v(2),
            warp_col_tiles: v(3),
            chunk: v(4),
            reorder_inner: v(5),
            dup_aware: flags[0],
            reg_packing: flags[1],
            nhwcnc_layout: flags[2],
        }
    }

    /// Invert [`SearchSpace::decode`]: the genotype whose knob values
    /// reproduce `cfg`, or `None` when a value falls outside the knob
    /// domain (e.g. a hand-written config with `chunk: 16`) or — with
    /// pinned flags — when `cfg`'s flags contradict the pins. This is
    /// how a cached schedule from a *neighboring* shape re-enters this
    /// shape's space as a warm-start seed.
    pub fn encode(&self, cfg: &ScheduleConfig) -> Option<Genotype> {
        let fields: [usize; 6] = [
            cfg.blk_row_warps,
            cfg.blk_col_warps,
            cfg.warp_row_tiles,
            cfg.warp_col_tiles,
            cfg.chunk,
            cfg.reorder_inner,
        ];
        let flags = [cfg.dup_aware, cfg.reg_packing, cfg.nhwcnc_layout];
        if !self.opts.search_opt_flags && flags != self.opts.pinned_flags {
            return None;
        }
        let mut g = Genotype::with_capacity(self.knobs.len());
        for (i, knob) in self.knobs.iter().enumerate() {
            let value = if i < fields.len() { fields[i] } else { flags[i - fields.len()] as usize };
            g.push(knob.values.iter().position(|&v| v == value)? as u8);
        }
        Some(g)
    }

    /// Genotype from a flat index (row-major over knob values).
    pub fn from_index(&self, mut idx: usize) -> Genotype {
        let mut g = vec![0u8; self.knobs.len()];
        for (i, k) in self.knobs.iter().enumerate().rev() {
            g[i] = (idx % k.values.len()) as u8;
            idx /= k.values.len();
        }
        g
    }

    /// Whether the decoded schedule's tiles divide this workload's
    /// (padded, per-group) GEMM exactly.
    pub fn is_legal(&self, g: &Genotype) -> bool {
        let (m, n, k) = self.gemm;
        self.decode(g).is_legal_for(m, n, k)
    }

    /// Every legal genotype (exhaustive search / Table 1's "Exhaustive").
    pub fn enumerate_legal(&self) -> Vec<Genotype> {
        (0..self.cardinality())
            .map(|i| self.from_index(i))
            .filter(|g| self.is_legal(g))
            .collect()
    }

    /// Whether the space admits at least one legal schedule. Early-exits
    /// on the first legal genotype (cheap for tileable workloads, one
    /// full scan for untileable ones — e.g. a matmul whose raw K no
    /// `block_k` divides). [`crate::tuner::Session`] checks this before
    /// tuning so an untileable workload errors instead of burning its
    /// trial budget on rejection sampling.
    pub fn has_legal(&self) -> bool {
        (0..self.cardinality()).any(|i| self.is_legal(&self.from_index(i)))
    }

    /// Uniform random *legal* genotype (rejection sampling; every conv
    /// workload admits the all-minimum genotype so this terminates with a
    /// legal result). Caveat: on a space with **no** legal genotypes at
    /// all (possible for raw-legality matmuls), the fallback below is
    /// itself illegal — callers that may face such spaces must gate on
    /// [`SearchSpace::has_legal`] or re-check [`SearchSpace::is_legal`].
    pub fn random_legal(&self, rng: &mut Rng) -> Genotype {
        for _ in 0..10_000 {
            let g: Genotype = self
                .knobs
                .iter()
                .map(|k| rng.gen_range(k.values.len()) as u8)
                .collect();
            if self.is_legal(&g) {
                return g;
            }
        }
        // fall back to the minimal schedule (legal for every conv; for a
        // legal-space-empty matmul there is nothing legal to return)
        vec![0u8; self.knobs.len()]
    }

    /// AutoTVM's proposal move: mutate exactly one random knob to a
    /// different random value, re-rolling until legal.
    pub fn mutate_one_knob(&self, g: &Genotype, rng: &mut Rng) -> Genotype {
        for _ in 0..1_000 {
            let mut out = g.clone();
            let i = rng.gen_range(self.knobs.len());
            let n_vals = self.knobs[i].values.len();
            if n_vals < 2 {
                continue;
            }
            let mut nv = rng.gen_range(n_vals) as u8;
            if nv == g[i] {
                nv = (nv + 1) % n_vals as u8;
            }
            out[i] = nv;
            if self.is_legal(&out) {
                return out;
            }
        }
        g.clone()
    }

    /// Configuration distance: number of differing knobs (Hamming). This is
    /// the diversity measure of §3.4 — "not all knobs of configuration are
    /// critical", so distance counts *which* knobs differ, not how much.
    pub fn distance(a: &Genotype, b: &Genotype) -> usize {
        a.iter().zip(b).filter(|(x, y)| x != y).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvWorkload;
    use crate::workload::MatmulWorkload;

    fn space() -> SearchSpace {
        SearchSpace::for_workload(
            &ConvWorkload::resnet50_stage(2, 8),
            SpaceOptions::default(),
        )
    }

    #[test]
    fn cardinality_counts_flags() {
        assert_eq!(space().cardinality(), 4 * 4 * 4 * 4 * 4 * 2 * 2 * 2 * 2);
        let tvm = SearchSpace::for_workload(
            &ConvWorkload::resnet50_stage(2, 8),
            SpaceOptions::autotvm_original(),
        );
        assert_eq!(tvm.cardinality(), 4usize.pow(5) * 2);
    }

    #[test]
    fn from_index_roundtrip_decode() {
        let s = space();
        let g = s.from_index(12345 % s.cardinality());
        assert_eq!(g.len(), s.n_knobs());
        let _ = s.decode(&g); // must not panic
    }

    #[test]
    fn encode_inverts_decode() {
        let s = space();
        let mut rng = Rng::new(3);
        for _ in 0..64 {
            let g = s.random_legal(&mut rng);
            let cfg = s.decode(&g);
            assert_eq!(s.encode(&cfg), Some(g));
        }
        // out-of-domain values don't encode
        let wild = ScheduleConfig { chunk: 16, ..Default::default() };
        assert_eq!(s.encode(&wild), None);
        // pinned-flag spaces reject configs contradicting the pins
        let pinned = SearchSpace::for_workload(
            &ConvWorkload::resnet50_stage(2, 8),
            SpaceOptions::baseline(),
        );
        assert_eq!(pinned.encode(&ScheduleConfig::default()), None, "default flags are all-on");
        let off = ScheduleConfig {
            dup_aware: false,
            reg_packing: false,
            nhwcnc_layout: false,
            ..Default::default()
        };
        let g = pinned.encode(&off).expect("matching pins encode");
        assert_eq!(pinned.decode(&g), off);
    }

    #[test]
    fn enumerate_legal_all_divide() {
        let s = space();
        let legal = s.enumerate_legal();
        assert!(!legal.is_empty());
        for g in &legal {
            let c = s.decode(g);
            // stage2 gemm: 25088 x 64 x 576
            assert_eq!(25088 % c.block_m(), 0);
            assert_eq!(64 % c.block_n(), 0);
            assert_eq!(576 % c.block_k(), 0);
        }
        // and nothing illegal sneaks in: count against a direct filter
        let direct = (0..s.cardinality())
            .filter(|&i| s.is_legal(&s.from_index(i)))
            .count();
        assert_eq!(legal.len(), direct);
    }

    #[test]
    fn random_legal_is_legal() {
        let s = space();
        let mut rng = Rng::new(7);
        for _ in 0..64 {
            assert!(s.is_legal(&s.random_legal(&mut rng)));
        }
    }

    #[test]
    fn mutation_changes_at_most_one_knob_and_stays_legal() {
        let s = space();
        let mut rng = Rng::new(11);
        let g = s.random_legal(&mut rng);
        for _ in 0..64 {
            let m = s.mutate_one_knob(&g, &mut rng);
            assert!(s.is_legal(&m));
            assert!(SearchSpace::distance(&g, &m) <= 1);
        }
    }

    #[test]
    fn pinned_flags_apply() {
        let s = SearchSpace::for_workload(
            &ConvWorkload::resnet50_stage(2, 8),
            SpaceOptions::baseline(),
        );
        let c = s.decode(&s.from_index(0));
        assert!(!c.dup_aware && !c.reg_packing && !c.nhwcnc_layout);
    }

    #[test]
    fn grouped_and_depthwise_spaces_are_nonempty_and_atom_aligned() {
        // resnext-style: per-group (4, 36) pads to (8, 64); depthwise
        // (1, 9) pads to one (8, 32) atom, admitting exactly the
        // narrowest column/chunk tilings
        let gx = SearchSpace::for_workload(
            &ConvWorkload::new("gx", 8, 56, 56, 128, 128).with_groups(32),
            SpaceOptions::default(),
        );
        let legal = gx.enumerate_legal();
        assert!(!legal.is_empty());
        for g in &legal {
            let c = gx.decode(g);
            assert!(c.block_n() <= 8);
            assert!(c.block_k() <= 64);
        }
        let dw = SearchSpace::for_workload(
            &ConvWorkload::new("dw", 1, 8, 8, 64, 64).depthwise(),
            SpaceOptions::default(),
        );
        let legal = dw.enumerate_legal();
        assert!(!legal.is_empty());
        for g in &legal {
            let c = dw.decode(g);
            assert_eq!(c.block_n(), 8, "depthwise pads N to one atom");
            assert_eq!(c.block_k(), 32, "depthwise pads K to one K-group");
        }
    }

    #[test]
    fn matmul_space_judges_raw_gemm() {
        // bert-ffn-shaped GEMM: every legal schedule divides the raw
        // (M, N, K) — no atom padding is interposed
        let mm = MatmulWorkload::new("mm_space", 1024, 768, 768);
        let s = SearchSpace::for_workload(&mm, SpaceOptions::default());
        let legal = s.enumerate_legal();
        assert!(!legal.is_empty());
        for g in &legal {
            let c = s.decode(g);
            assert_eq!(1024 % c.block_m(), 0);
            assert_eq!(768 % c.block_n(), 0);
            assert_eq!(768 % c.block_k(), 0);
        }
        // a K that no block_k divides admits no schedule at all
        let odd = MatmulWorkload::new("odd_k", 1024, 768, 48);
        let s = SearchSpace::for_workload(&odd, SpaceOptions::default());
        assert!(s.enumerate_legal().is_empty());
        assert!(!s.has_legal());
        // ...while every conv space (and this aligned matmul) has one
        assert!(space().has_legal());
        assert!(SearchSpace::for_workload(&mm, SpaceOptions::default()).has_legal());
    }

    #[test]
    fn distance_is_hamming() {
        let a = vec![0, 1, 2, 3, 0, 1, 0, 0, 0];
        let b = vec![0, 1, 0, 3, 0, 0, 0, 0, 1];
        assert_eq!(SearchSpace::distance(&a, &b), 3);
    }
}
