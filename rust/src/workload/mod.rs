//! The operator abstraction: what the tuning/serving stack knows about a
//! workload, independent of *which* operator it is.
//!
//! The paper's method — a tile/warp search space over reduced-precision
//! MMA atoms, explored by learning from distinctive candidates — is not
//! conv-specific: its operand-shape constraints apply to any int4/int8
//! GEMM-shaped kernel on Tensor Cores (related work treats plain matrix
//! multiply as *the* canonical Tensor Core workload). This module is the
//! seam that keeps the rest of the stack operator-generic:
//!
//! * [`Workload`] — the trait every operator implements: a GEMM view
//!   (`m`/`n`/`k` plus MMA-atom-padded variants and the legality view),
//!   [`Precision`], the per-row-block duplicate profile and coalescing
//!   model the simulator charges, the workload-context feature
//!   contribution the cost model trains on, the namespaced `kind` string
//!   the registry and server route by, and a JSON round-trip.
//! * [`OpWorkload`] — the enum dispatch used at serialization and serving
//!   boundaries (`Conv` | `Matmul`); everything internal takes
//!   `&dyn Workload` or stores an `OpWorkload`.
//! * [`OpInstance`] / [`OpScratch`] — the executable counterpart: a
//!   request payload the serving workers run under a tuned schedule,
//!   whatever the operator.
//!
//! [`MatmulWorkload`] (in [`matmul`]) is the second first-class operator:
//! a quantized GEMM reusing the conv executor's blocked i32 GEMM and the
//! padded INT4 packing.

pub mod matmul;

pub use matmul::{
    qmatmul, qmatmul_accumulate_with, qmatmul_scheduled, qmatmul_scheduled_with, MatmulInstance,
    MatmulScratch, MatmulWorkload,
};

use anyhow::{anyhow, bail, Result};

use crate::conv::{qconv2d_scheduled_with, ConvInstance, ConvWorkload, ExecScratch};
use crate::quant::Epilogue;
use crate::searchspace::{ScheduleConfig, MMA_N};
use crate::util::Json;

/// Reduced-precision data type of a workload (paper §1: the MMA operand
/// group doubles as the bit width halves — T4 INT4 MMA takes an 8x32
/// operand, twice INT8's 8x16 — doubling peak throughput).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// 4-bit integers: 8x32 MMA operand group, the paper's headline
    /// deployment precision.
    #[default]
    Int4,
    /// 8-bit integers: 8x16 MMA operand group, half the INT4 peak rate.
    Int8,
}

impl Precision {
    /// Bytes per element (INT4 packs two per byte).
    pub fn element_bytes(self) -> f64 {
        match self {
            Precision::Int4 => 0.5,
            Precision::Int8 => 1.0,
        }
    }

    /// K-group of one MMA instruction.
    pub fn mma_k(self) -> usize {
        match self {
            Precision::Int4 => 32,
            Precision::Int8 => 16,
        }
    }

    /// Values packed per 32-bit register.
    pub fn pack_factor(self) -> usize {
        match self {
            Precision::Int4 => 8,
            Precision::Int8 => 4,
        }
    }

    /// The serialization tag (`"int4"` / `"int8"`).
    pub fn tag(self) -> &'static str {
        match self {
            Precision::Int4 => "int4",
            Precision::Int8 => "int8",
        }
    }

    /// Parse the [`Precision::tag`] form back.
    pub fn from_tag(tag: &str) -> Result<Self> {
        match tag {
            "int4" => Ok(Precision::Int4),
            "int8" => Ok(Precision::Int8),
            other => bail!("unknown precision '{other}' (valid: int4, int8)"),
        }
    }
}

/// Number of workload-context features every operator contributes to the
/// cost model's feature vector (see [`Workload::context_features`]).
pub const CONTEXT_FEATURES: usize = 4;

/// Duplicate/padding statistics of one M-row-block of the GEMM's feature
/// operand — what the simulator's traffic model charges.
///
/// For a convolution the im2col duplicates live *across kernel positions*
/// (paper Fig. 3): the same feature element appears at several columns, so
/// a duplicate-aware block loads its pixels' receptive-field patch once
/// (`unique_per_row_block`) where a naive im2col load touches every
/// non-padding cell (`naive_per_row_block`). A plain matrix multiply has
/// no duplicates: naive and unique coincide.
#[derive(Debug, Clone, Copy)]
pub struct FeatureTileProfile {
    /// Operand loads a naive (duplicate-oblivious) block issues across a
    /// (block_m x K) row-block.
    pub naive_per_row_block: f64,
    /// Distinct operand elements across the row-block — what a
    /// duplicate-aware block loads, and what DRAM serves cold.
    pub unique_per_row_block: f64,
    /// Distinct source positions behind the row-block
    /// (`unique_per_row_block / staging channels`) — sizes the raw-patch
    /// staging buffer.
    pub unique_pixels: f64,
}

/// Clamped log2 used for every log-scaled feature dim — one definition
/// shared by [`Workload::context_features`] impls and
/// [`crate::costmodel::featurize`]'s geometry dims, so the two halves of
/// the feature space can never drift apart.
pub(crate) fn lg(x: usize) -> f64 {
    (x.max(1) as f64).log2()
}

/// One operator workload, as seen by the search space, the simulator, the
/// cost model, the registry and the serving router.
///
/// The trait deliberately speaks only the GEMM language: every method is
/// answerable from the workload's lowered matrix view plus whatever static
/// structure the operator knows about its own operand (conv: the im2col
/// index algebra; matmul: nothing special). Anything conv-only stays on
/// [`ConvWorkload`]'s inherent API.
pub trait Workload: std::fmt::Debug {
    /// Workload key (unique per shape; the un-namespaced half of
    /// [`Workload::kind`]).
    fn name(&self) -> &str;

    /// Operator family tag (`"conv"`, `"matmul"`) — the namespace of the
    /// registry/serving kind.
    fn op_name(&self) -> &'static str;

    /// The namespaced registry/serving kind, `"<op>:<name>"` — what
    /// `tune-net` writes, the schedule registry keys by, and requests
    /// route on.
    fn kind(&self) -> String {
        format!("{}:{}", self.op_name(), self.name())
    }

    /// Reduced-precision data type.
    fn precision(&self) -> Precision;

    /// GEMM rows.
    fn gemm_m(&self) -> usize;

    /// GEMM columns (*per group*, unpadded — real outputs).
    fn gemm_n(&self) -> usize;

    /// GEMM accumulation depth (*per group*, unpadded).
    fn gemm_k(&self) -> usize;

    /// [`Workload::gemm_n`] padded up to the 8-column WMMA atom.
    fn gemm_n_padded(&self) -> usize {
        self.gemm_n().div_ceil(MMA_N) * MMA_N
    }

    /// [`Workload::gemm_k`] padded up to this precision's MMA K-group.
    fn gemm_k_padded(&self) -> usize {
        let kg = self.precision().mma_k();
        self.gemm_k().div_ceil(kg) * kg
    }

    /// The (M, N, K) view tile legality is judged on — also the compute
    /// grid the simulator charges. Convolutions pad N/K to the MMA atom
    /// (a depthwise conv tiles one padded 8x32 atom, not its raw (1, 9)
    /// GEMM); a plain matmul judges the raw (M, N, K).
    fn legality_gemm(&self) -> (usize, usize, usize) {
        (self.gemm_m(), self.gemm_n_padded(), self.gemm_k_padded())
    }

    /// Independent GEMM grids this workload launches (conv channel
    /// groups; `1` for dense operators).
    fn groups(&self) -> usize {
        1
    }

    /// Multiply-accumulate operation count, x2 (the GFLOPS denominator).
    fn ops(&self) -> u64 {
        2 * self.groups() as u64
            * self.gemm_m() as u64
            * self.gemm_n() as u64
            * self.gemm_k() as u64
    }

    /// Paper §4.4 taxonomy: whether the operand is "larger height &
    /// width" rather than "larger channels & filters". Only convolutions
    /// have a spatial axis; dense GEMMs are channel-shaped by definition.
    fn is_spatial_heavy(&self) -> bool {
        false
    }

    /// Channels resident per staged source position — sizes the
    /// duplicate-aware staging buffer (conv: input channels per group;
    /// matmul: the whole K axis).
    fn staging_channels(&self) -> usize {
        self.gemm_k()
    }

    /// Cache key covering everything [`Workload::row_block_profile`]
    /// depends on: a 64-bit hash of the operator tag plus the **full
    /// operand value** — never just the name, so same-named workloads of
    /// different shapes (or operators) can share one
    /// [`ProfileCache`](crate::sim::ProfileCache) without receiving each
    /// other's profiles. A hash (not a formatted string) keeps the
    /// per-measurement cache lookup allocation-free.
    fn profile_key(&self) -> u64;

    /// Operand-load statistics of one (block_m x K) row-block. The default
    /// models a dense operand with no duplicates (every cell is a distinct
    /// element); convolutions override it with the exact im2col duplicate
    /// analysis.
    fn row_block_profile(&self, block_m: usize) -> FeatureTileProfile {
        let cells = block_m as f64 * self.gemm_k() as f64;
        FeatureTileProfile {
            naive_per_row_block: cells,
            unique_per_row_block: cells,
            unique_pixels: cells / self.staging_channels().max(1) as f64,
        }
    }

    /// Coalescing efficiency of the operand's global loads under the
    /// schedule's layout flag (1.0 = every transaction byte useful). A
    /// row-major matmul operand is naturally coalesced either way;
    /// convolutions derive this from WMMA-tile byte addresses.
    fn coalesce_efficiency(&self, nhwcnc: bool) -> f64 {
        let _ = nhwcnc;
        1.0
    }

    /// The [`CONTEXT_FEATURES`] workload-context dims of the cost-model
    /// feature vector — what lets one model rank across workloads (and
    /// operators) for transfer learning.
    fn context_features(&self) -> [f64; CONTEXT_FEATURES];

    /// Serialize to the tagged-object JSON schema ([`OpWorkload::from_json`]
    /// parses it back).
    fn to_json(&self) -> Json;
}

impl Workload for ConvWorkload {
    fn name(&self) -> &str {
        &self.name
    }

    fn op_name(&self) -> &'static str {
        "conv"
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn gemm_m(&self) -> usize {
        ConvWorkload::gemm_m(self)
    }

    fn gemm_n(&self) -> usize {
        ConvWorkload::gemm_n(self)
    }

    fn gemm_k(&self) -> usize {
        ConvWorkload::gemm_k(self)
    }

    fn gemm_n_padded(&self) -> usize {
        ConvWorkload::gemm_n_padded(self)
    }

    fn gemm_k_padded(&self) -> usize {
        ConvWorkload::gemm_k_padded(self)
    }

    fn groups(&self) -> usize {
        self.groups
    }

    fn ops(&self) -> u64 {
        ConvWorkload::ops(self)
    }

    fn is_spatial_heavy(&self) -> bool {
        ConvWorkload::is_spatial_heavy(self)
    }

    fn staging_channels(&self) -> usize {
        self.in_channels_per_group()
    }

    /// Hash of the operator tag and the whole conv value — covers every
    /// field the im2col row-block statistics depend on (and a few they
    /// don't, which only splits entries, never aliases them).
    fn profile_key(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        "conv".hash(&mut h);
        self.hash(&mut h);
        h.finish()
    }

    /// Exact row-block statistics from the im2col index algebra, sampled
    /// at the first / middle / last block rows and averaged (interior
    /// blocks dominate and are translation-invariant, so three samples
    /// suffice).
    fn row_block_profile(&self, block_m: usize) -> FeatureTileProfile {
        let ix = self.im2col(); // group 0 stands in for every group
        let rows = ix.rows();
        let cols = ix.cols();
        let n_row_blocks = rows.div_ceil(block_m).max(1);
        let row_samples = [0, n_row_blocks / 2, n_row_blocks.saturating_sub(1)];

        let mut naive = 0.0;
        let mut unique = 0.0;
        for &rb in row_samples.iter() {
            let s = ix.tile_stats(rb * block_m, block_m, 0, cols);
            naive += s.naive_loads() as f64;
            unique += s.unique as f64;
        }
        let n = row_samples.len() as f64;
        FeatureTileProfile {
            naive_per_row_block: naive / n,
            unique_per_row_block: unique / n,
            unique_pixels: unique / n / self.in_channels_per_group() as f64,
        }
    }

    /// Derived from WMMA-tile byte addresses over the NHWC / NHWCnc
    /// feature map (the §3.3 coalescing analysis).
    fn coalesce_efficiency(&self, nhwcnc: bool) -> f64 {
        use crate::layout::{self, Layout, TensorDims};
        let eb = self.precision.element_bytes();
        let dims = TensorDims {
            n: self.batch.max(layout::WMMA_TILE_ROWS),
            h: self.height,
            w: self.width,
            // channel bytes at the workload's precision
            c: ((self.in_channels as f64 * eb) as usize).max(layout::WMMA_TILE_BYTES_PER_ROW),
        };
        let lay = if nhwcnc { Layout::Nhwcnc } else { Layout::Nhwc };
        layout::wmma_tile_coalescing(&dims, lay).efficiency()
    }

    fn context_features(&self) -> [f64; CONTEXT_FEATURES] {
        [
            lg(self.height * self.width),
            lg(self.in_channels),
            lg(self.groups),
            lg(self.dilation),
        ]
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("op", Json::Str("conv".into())),
            ("name", Json::Str(self.name.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("height", Json::Num(self.height as f64)),
            ("width", Json::Num(self.width as f64)),
            ("in_channels", Json::Num(self.in_channels as f64)),
            ("out_channels", Json::Num(self.out_channels as f64)),
            ("kernel", Json::Num(self.kernel as f64)),
            ("stride", Json::Num(self.stride as f64)),
            ("padding", Json::Num(self.padding as f64)),
            ("groups", Json::Num(self.groups as f64)),
            ("dilation", Json::Num(self.dilation as f64)),
            ("precision", Json::Str(self.precision.tag().into())),
        ])
    }
}

fn conv_from_json(j: &Json) -> Result<ConvWorkload> {
    let num = |k: &str| -> Result<usize> {
        j.req(k)?
            .as_usize()
            .ok_or_else(|| anyhow!("conv workload key '{k}' not an integer"))
    };
    // validate the builder invariants the struct relies on — malformed
    // JSON must error here, not divide-by-zero (groups/stride 0) or
    // silently miscompute (groups not dividing the channels) downstream
    let pos = |k: &str| -> Result<usize> {
        let v = num(k)?;
        if v == 0 {
            bail!("conv workload key '{k}' must be >= 1");
        }
        Ok(v)
    };
    let (in_channels, out_channels) = (pos("in_channels")?, pos("out_channels")?);
    let groups = pos("groups")?;
    if in_channels % groups != 0 || out_channels % groups != 0 {
        bail!(
            "conv workload groups {groups} must divide in_channels {in_channels} \
             and out_channels {out_channels}"
        );
    }
    let mut wl = ConvWorkload::new(
        j.req("name")?
            .as_str()
            .ok_or_else(|| anyhow!("conv workload 'name' not a string"))?,
        pos("batch")?,
        pos("height")?,
        pos("width")?,
        in_channels,
        out_channels,
    );
    wl.kernel = pos("kernel")?;
    wl.stride = pos("stride")?;
    wl.padding = num("padding")?;
    wl.groups = groups;
    wl.dilation = pos("dilation")?;
    wl.precision = Precision::from_tag(
        j.req("precision")?
            .as_str()
            .ok_or_else(|| anyhow!("conv workload 'precision' not a string"))?,
    )?;
    Ok(wl)
}

/// Enum dispatch over the first-class operators — the concrete type the
/// stack stores and ships across serialization/serving boundaries
/// (internally everything speaks `&dyn Workload`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum OpWorkload {
    /// A 2-D convolution lowered to an im2col GEMM.
    Conv(ConvWorkload),
    /// A plain quantized matrix multiply.
    Matmul(MatmulWorkload),
}

impl OpWorkload {
    /// The inner workload as a trait object (for explicit dispatch).
    pub fn as_workload(&self) -> &dyn Workload {
        match self {
            OpWorkload::Conv(w) => w,
            OpWorkload::Matmul(w) => w,
        }
    }

    /// The conv inside, if this is one.
    pub fn as_conv(&self) -> Option<&ConvWorkload> {
        match self {
            OpWorkload::Conv(w) => Some(w),
            _ => None,
        }
    }

    /// The matmul inside, if this is one.
    pub fn as_matmul(&self) -> Option<&MatmulWorkload> {
        match self {
            OpWorkload::Matmul(w) => Some(w),
            _ => None,
        }
    }

    /// Workload key (see [`Workload::name`]).
    pub fn name(&self) -> &str {
        self.as_workload().name()
    }

    /// The namespaced registry/serving kind (see [`Workload::kind`]).
    pub fn kind(&self) -> String {
        self.as_workload().kind()
    }

    /// A deterministic synthetic problem instance of this workload (the
    /// serving demos' traffic generator).
    pub fn synthetic(&self, seed: u64) -> OpInstance {
        match self {
            OpWorkload::Conv(w) => OpInstance::Conv(ConvInstance::synthetic(w, seed)),
            OpWorkload::Matmul(w) => OpInstance::Matmul(MatmulInstance::synthetic(w, seed)),
        }
    }

    /// Parse the tagged-object schema [`Workload::to_json`] writes; the
    /// `"op"` tag selects the operator.
    pub fn from_json(j: &Json) -> Result<OpWorkload> {
        match j.req("op")?.as_str() {
            Some("conv") => Ok(OpWorkload::Conv(conv_from_json(j)?)),
            Some("matmul") => Ok(OpWorkload::Matmul(matmul::matmul_from_json(j)?)),
            Some(other) => bail!("unknown workload op '{other}' (valid: conv, matmul)"),
            None => bail!("workload 'op' tag not a string"),
        }
    }
}

impl Workload for OpWorkload {
    fn name(&self) -> &str {
        self.as_workload().name()
    }

    fn op_name(&self) -> &'static str {
        self.as_workload().op_name()
    }

    fn precision(&self) -> Precision {
        self.as_workload().precision()
    }

    fn gemm_m(&self) -> usize {
        self.as_workload().gemm_m()
    }

    fn gemm_n(&self) -> usize {
        self.as_workload().gemm_n()
    }

    fn gemm_k(&self) -> usize {
        self.as_workload().gemm_k()
    }

    fn gemm_n_padded(&self) -> usize {
        self.as_workload().gemm_n_padded()
    }

    fn gemm_k_padded(&self) -> usize {
        self.as_workload().gemm_k_padded()
    }

    fn legality_gemm(&self) -> (usize, usize, usize) {
        self.as_workload().legality_gemm()
    }

    fn groups(&self) -> usize {
        self.as_workload().groups()
    }

    fn ops(&self) -> u64 {
        self.as_workload().ops()
    }

    fn is_spatial_heavy(&self) -> bool {
        self.as_workload().is_spatial_heavy()
    }

    fn staging_channels(&self) -> usize {
        self.as_workload().staging_channels()
    }

    fn profile_key(&self) -> u64 {
        self.as_workload().profile_key()
    }

    fn row_block_profile(&self, block_m: usize) -> FeatureTileProfile {
        self.as_workload().row_block_profile(block_m)
    }

    fn coalesce_efficiency(&self, nhwcnc: bool) -> f64 {
        self.as_workload().coalesce_efficiency(nhwcnc)
    }

    fn context_features(&self) -> [f64; CONTEXT_FEATURES] {
        self.as_workload().context_features()
    }

    fn to_json(&self) -> Json {
        self.as_workload().to_json()
    }
}

impl From<ConvWorkload> for OpWorkload {
    fn from(w: ConvWorkload) -> Self {
        OpWorkload::Conv(w)
    }
}

impl From<&ConvWorkload> for OpWorkload {
    fn from(w: &ConvWorkload) -> Self {
        OpWorkload::Conv(w.clone())
    }
}

impl From<MatmulWorkload> for OpWorkload {
    fn from(w: MatmulWorkload) -> Self {
        OpWorkload::Matmul(w)
    }
}

impl From<&MatmulWorkload> for OpWorkload {
    fn from(w: &MatmulWorkload) -> Self {
        OpWorkload::Matmul(w.clone())
    }
}

impl From<&OpWorkload> for OpWorkload {
    fn from(w: &OpWorkload) -> Self {
        w.clone()
    }
}

/// One executable problem instance of either operator — what a serving
/// request carries.
#[derive(Debug, Clone)]
pub enum OpInstance {
    /// A quantized conv problem (NHWC feature map + HWIO weights).
    Conv(ConvInstance),
    /// A quantized matmul problem (row-major A and B).
    Matmul(MatmulInstance),
}

impl OpInstance {
    /// The workload this instance instantiates.
    pub fn workload(&self) -> OpWorkload {
        match self {
            OpInstance::Conv(i) => OpWorkload::Conv(i.wl.clone()),
            OpInstance::Matmul(i) => OpWorkload::Matmul(i.wl.clone()),
        }
    }

    /// Execute under the default schedule with fresh buffers.
    pub fn execute(&self, epi: &Epilogue) -> Vec<i32> {
        self.execute_scheduled(epi, &ScheduleConfig::default())
    }

    /// Execute under a specific schedule with fresh buffers.
    pub fn execute_scheduled(&self, epi: &Epilogue, cfg: &ScheduleConfig) -> Vec<i32> {
        self.execute_scheduled_with(epi, cfg, &mut OpScratch::new())
    }

    /// Execute under a specific schedule with caller-owned buffers — the
    /// batched serving hot path (each worker threads one [`OpScratch`]
    /// through its request stream). Output bits are schedule- and
    /// scratch-invariant for both operators.
    pub fn execute_scheduled_with(
        &self,
        epi: &Epilogue,
        cfg: &ScheduleConfig,
        scratch: &mut OpScratch,
    ) -> Vec<i32> {
        match self {
            OpInstance::Conv(i) => qconv2d_scheduled_with(i, epi, cfg, &mut scratch.conv),
            OpInstance::Matmul(i) => qmatmul_scheduled_with(i, epi, cfg, &mut scratch.matmul),
        }
    }
}

impl From<ConvInstance> for OpInstance {
    fn from(i: ConvInstance) -> Self {
        OpInstance::Conv(i)
    }
}

impl From<MatmulInstance> for OpInstance {
    fn from(i: MatmulInstance) -> Self {
        OpInstance::Matmul(i)
    }
}

/// Reusable execution buffers covering both operators — what a serving
/// worker owns for its lifetime. Each operator's scratch keeps its own
/// staging/accumulator buffers (and, for conv, the cached im2col gather
/// map), so same-kind batches stay allocation- and recompute-free
/// regardless of which operator the batch is.
#[derive(Debug, Default)]
pub struct OpScratch {
    conv: ExecScratch,
    matmul: MatmulScratch,
}

impl OpScratch {
    /// Empty scratch; buffers grow to the first workload's sizes on use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach the server-wide prepacked-weight cache to both operator
    /// halves: subsequent executions resolve their weight panels through
    /// [`crate::gemm::PrepackCache::get_or_pack`] instead of re-packing
    /// per call. Serving workers attach their server's shared cache once
    /// at startup.
    pub fn set_prepack(&mut self, cache: std::sync::Arc<crate::gemm::PrepackCache>) {
        self.conv.set_prepack(std::sync::Arc::clone(&cache));
        self.matmul.set_prepack(cache);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv() -> ConvWorkload {
        ConvWorkload::resnet50_stage(2, 8)
    }

    fn matmul() -> MatmulWorkload {
        MatmulWorkload::new("mm", 1024, 768, 768)
    }

    #[test]
    fn kinds_are_namespaced_per_operator() {
        assert_eq!(OpWorkload::from(conv()).kind(), "conv:resnet50_stage2");
        assert_eq!(OpWorkload::from(matmul()).kind(), "matmul:mm");
        assert_eq!(conv().op_name(), "conv");
        assert_eq!(matmul().op_name(), "matmul");
    }

    #[test]
    fn conv_trait_view_matches_inherent_api() {
        let wl = conv();
        let op: OpWorkload = (&wl).into();
        assert_eq!(Workload::gemm_m(&wl), wl.gemm_m());
        assert_eq!(op.gemm_n_padded(), wl.gemm_n_padded());
        assert_eq!(op.gemm_k_padded(), wl.gemm_k_padded());
        assert_eq!(op.legality_gemm(), (wl.gemm_m(), wl.gemm_n_padded(), wl.gemm_k_padded()));
        assert_eq!(Workload::ops(&op), wl.ops());
        assert_eq!(Workload::groups(&op), wl.groups);
    }

    #[test]
    fn matmul_legality_is_raw_conv_legality_is_padded() {
        // the depthwise conv pads (1, 9) to one (8, 32) atom...
        let dw = ConvWorkload::new("dw", 1, 8, 8, 64, 64).depthwise();
        assert_eq!(dw.legality_gemm(), (dw.gemm_m(), 8, 32));
        // ...while the matmul judges raw (M, N, K)
        let mm = matmul();
        assert_eq!(mm.legality_gemm(), (1024, 768, 768));
    }

    #[test]
    fn conv_profile_has_duplicates_matmul_does_not() {
        let c = conv().row_block_profile(32);
        assert!(c.naive_per_row_block > c.unique_per_row_block);
        let m = matmul().row_block_profile(32);
        assert_eq!(m.naive_per_row_block, m.unique_per_row_block);
        assert_eq!(m.naive_per_row_block, 32.0 * 768.0);
    }

    #[test]
    fn coalescing_conv_layout_sensitive_matmul_not() {
        let wl = conv();
        assert!((Workload::coalesce_efficiency(&wl, true) - 1.0).abs() < 1e-9);
        assert!(Workload::coalesce_efficiency(&wl, false) < 0.75);
        let mm = matmul();
        assert_eq!(mm.coalesce_efficiency(true), 1.0);
        assert_eq!(mm.coalesce_efficiency(false), 1.0);
    }

    #[test]
    fn json_roundtrips_both_operators() {
        for op in [
            OpWorkload::from(conv()),
            OpWorkload::from(ConvWorkload::new("g", 2, 9, 9, 16, 32).with_groups(4).with_dilation(2)),
            OpWorkload::from(matmul()),
            OpWorkload::from(matmul().with_precision(Precision::Int8)),
        ] {
            let text = op.to_json().to_string();
            let back = OpWorkload::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, op);
        }
        // unknown op tags error
        let j = Json::parse(r#"{"op": "softmax", "name": "x"}"#).unwrap();
        assert!(OpWorkload::from_json(&j).is_err());
    }

    #[test]
    fn from_json_validates_builder_invariants() {
        // malformed JSON must error at parse time, not divide-by-zero or
        // silently miscompute downstream
        let base = OpWorkload::from(conv()).to_json().to_string();
        for (field, bad) in [("\"groups\":1", "\"groups\":0"),
                             ("\"stride\":1", "\"stride\":0"),
                             ("\"groups\":1", "\"groups\":3")] {
            let text = base.replacen(field, bad, 1);
            assert_ne!(text, base, "fixture must actually change {field}");
            let j = Json::parse(&text).unwrap();
            assert!(OpWorkload::from_json(&j).is_err(), "{bad} must be rejected");
        }
        let mm = OpWorkload::from(matmul()).to_json().to_string();
        let text = mm.replacen("\"k\":768", "\"k\":0", 1);
        assert_ne!(text, mm);
        assert!(OpWorkload::from_json(&Json::parse(&text).unwrap()).is_err());
    }

    #[test]
    fn context_features_distinguish_operators() {
        let c = Workload::context_features(&conv());
        let m = matmul().context_features();
        assert_ne!(c, m);
        for f in c.iter().chain(m.iter()) {
            assert!(f.is_finite());
        }
    }

    #[test]
    fn op_instance_executes_either_operator() {
        let epi = Epilogue::default();
        let cwl = ConvWorkload::new("oi_c", 1, 6, 6, 8, 8);
        let conv_inst: OpInstance = ConvInstance::synthetic(&cwl, 3).into();
        let mwl = MatmulWorkload::new("oi_m", 16, 16, 32);
        let mm_inst = OpWorkload::from(&mwl).synthetic(3);
        let mut scratch = OpScratch::new();
        for inst in [&conv_inst, &mm_inst] {
            let want = inst.execute(&epi);
            let got = inst.execute_scheduled_with(
                &epi,
                &ScheduleConfig::default(),
                &mut scratch,
            );
            assert_eq!(got, want);
        }
        assert_eq!(conv_inst.workload().name(), "oi_c");
        assert_eq!(mm_inst.workload().kind(), "matmul:oi_m");
    }

    #[test]
    fn precision_tags_roundtrip() {
        for p in [Precision::Int4, Precision::Int8] {
            assert_eq!(Precision::from_tag(p.tag()).unwrap(), p);
        }
        assert!(Precision::from_tag("fp16").is_err());
    }
}
