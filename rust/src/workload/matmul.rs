//! Quantized matrix multiply — the second first-class operator.
//!
//! A plain (M x K) by (K x N) GEMM in the INT4/INT8 domain: exactly the
//! kernel shape the paper's tile/warp search space was built for, minus
//! the im2col lowering (related work — Bhaskaracharya et al., Markidis et
//! al. — treats this as the canonical Tensor Core workload). Execution
//! reuses the conv executor's pipelined i32 microkernel
//! ([`crate::gemm::gemm_i32_pipelined`], prepack-cache aware) and the
//! padded INT4 packing ([`crate::quant::pack_int4_padded_into`]), so
//! matmul numerics inherit the conv path's golden-validated integer
//! pipeline.
//!
//! Unlike a convolution — whose per-group GEMM is padded up to the MMA
//! atom before legality is judged — a matmul's tile legality is judged on
//! the **raw (M, N, K)**: there is no im2col structure to hide padding
//! behind, so a shape either tiles exactly or admits no schedule.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::gemm::{
    default_bn, gemm_i32_pipelined, operand_fingerprint, GemmScratch, PrepackCache,
};
use crate::quant::{pack_int4_padded_into, Epilogue};
use crate::searchspace::ScheduleConfig;
use crate::util::Json;

use super::{lg, Precision, Workload, CONTEXT_FEATURES};

/// A quantized GEMM workload: `(m x k) . (k x n)` at reduced precision.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct MatmulWorkload {
    /// Workload key — the un-namespaced half of the `matmul:<name>`
    /// registry/serving kind.
    pub name: String,
    /// Output rows (e.g. `batch x sequence` for a transformer GEMM).
    pub m: usize,
    /// Output columns.
    pub n: usize,
    /// Accumulation depth.
    pub k: usize,
    /// Reduced-precision data type (INT4 or INT8).
    pub precision: Precision,
}

impl MatmulWorkload {
    /// An INT4 GEMM of the given shape; adjust with
    /// [`MatmulWorkload::with_precision`].
    pub fn new(name: impl Into<String>, m: usize, n: usize, k: usize) -> Self {
        Self { name: name.into(), m, n, k, precision: Precision::Int4 }
    }

    /// Same GEMM at a different precision.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

impl Workload for MatmulWorkload {
    fn name(&self) -> &str {
        &self.name
    }

    fn op_name(&self) -> &'static str {
        "matmul"
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn gemm_m(&self) -> usize {
        self.m
    }

    fn gemm_n(&self) -> usize {
        self.n
    }

    fn gemm_k(&self) -> usize {
        self.k
    }

    /// Raw (M, N, K): a matmul has no im2col padding to tile over, so a
    /// schedule is legal only if it divides the real operand exactly.
    fn legality_gemm(&self) -> (usize, usize, usize) {
        (self.m, self.n, self.k)
    }

    fn profile_key(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        "matmul".hash(&mut h);
        self.hash(&mut h);
        h.finish()
    }

    fn context_features(&self) -> [f64; CONTEXT_FEATURES] {
        // a GEMM is "all channels": M and K describe the operand, and the
        // spatial/group/dilation dims a conv would report are identity
        [lg(self.m), lg(self.k), 0.0, 0.0]
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("op", Json::Str("matmul".into())),
            ("name", Json::Str(self.name.clone())),
            ("m", Json::Num(self.m as f64)),
            ("n", Json::Num(self.n as f64)),
            ("k", Json::Num(self.k as f64)),
            ("precision", Json::Str(self.precision.tag().into())),
        ])
    }
}

/// Parse the schema [`MatmulWorkload`]'s `to_json` writes (called from
/// [`super::OpWorkload::from_json`] once the `"op"` tag selected matmul).
pub(super) fn matmul_from_json(j: &Json) -> Result<MatmulWorkload> {
    let num = |k: &str| -> Result<usize> {
        let v = j
            .req(k)?
            .as_usize()
            .ok_or_else(|| anyhow!("matmul workload key '{k}' not an integer"))?;
        if v == 0 {
            anyhow::bail!("matmul workload key '{k}' must be >= 1");
        }
        Ok(v)
    };
    Ok(MatmulWorkload {
        name: j
            .req("name")?
            .as_str()
            .ok_or_else(|| anyhow!("matmul workload 'name' not a string"))?
            .to_string(),
        m: num("m")?,
        n: num("n")?,
        k: num("k")?,
        precision: Precision::from_tag(
            j.req("precision")?
                .as_str()
                .ok_or_else(|| anyhow!("matmul workload 'precision' not a string"))?,
        )?,
    })
}

/// A quantized matmul problem instance: INT4/INT8-domain values held in
/// i8 (the same value domain the conv executor uses).
#[derive(Debug, Clone)]
pub struct MatmulInstance {
    /// The GEMM shape this data instantiates.
    pub wl: MatmulWorkload,
    /// Row-major `m x k` left operand, values in [-8, 7].
    pub a: Vec<i8>,
    /// Row-major `k x n` right operand, values in [-8, 7].
    pub b: Vec<i8>,
    /// Per-output-column bias.
    pub bias: Vec<i32>,
}

impl MatmulInstance {
    /// Deterministic synthetic instance (same value domain as
    /// [`crate::conv::ConvInstance::synthetic`]).
    pub fn synthetic(wl: &MatmulWorkload, seed: u64) -> Self {
        let mut rng = crate::util::Rng::new(seed);
        let a = (0..wl.m * wl.k).map(|_| rng.gen_range(16) as i8 - 8).collect();
        let b = (0..wl.k * wl.n).map(|_| rng.gen_range(16) as i8 - 8).collect();
        let bias = (0..wl.n).map(|_| rng.gen_range(128) as i32 - 64).collect();
        Self { wl: wl.clone(), a, b, bias }
    }
}

/// Reusable matmul execution buffers (the accumulator and the epilogue
/// row buffer); the matmul half of [`super::OpScratch`].
#[derive(Debug, Default)]
pub struct MatmulScratch {
    acc: Vec<i32>,
    rowbuf: Vec<i32>,
    /// Microkernel staging buffers plus the scratch-owned packed-weight
    /// buffer for the uncached path (mirrors the conv executor's scratch).
    gemm: GemmScratch,
    /// Server-wide prepacked-weight cache, when attached (see
    /// [`MatmulScratch::set_prepack`]).
    prepack: Option<Arc<PrepackCache>>,
}

impl MatmulScratch {
    /// Empty scratch; buffers grow to the first workload's sizes on use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach the server-wide [`PrepackCache`] — same contract as
    /// [`crate::conv::ExecScratch::set_prepack`].
    pub fn set_prepack(&mut self, cache: Arc<PrepackCache>) {
        self.prepack = Some(cache);
    }

    /// The i32 accumulator left by the most recent
    /// [`qmatmul_accumulate_with`] call: row-major `(m x n)`. The graph
    /// executor reads it to run the fused
    /// [`crate::quant::RequantParams`] epilogue.
    pub fn accumulator(&self) -> &[i32] {
        &self.acc
    }
}

/// Execute the matmul under the default schedule, returning packed-INT4
/// words, row-major over `(m, n/8)` — the same output layout as the conv
/// executor (rows padded to the packing granule when `n % 8 != 0`).
pub fn qmatmul(inst: &MatmulInstance, epi: &Epilogue) -> Vec<i32> {
    qmatmul_scheduled(inst, epi, &ScheduleConfig::default())
}

/// Execute the matmul under a specific schedule — the serving path. On
/// this CPU substrate the schedule steers the GEMM blocking only;
/// numerics are schedule-invariant by construction (pinned by the
/// conformance harness).
pub fn qmatmul_scheduled(
    inst: &MatmulInstance,
    epi: &Epilogue,
    cfg: &ScheduleConfig,
) -> Vec<i32> {
    qmatmul_scheduled_with(inst, epi, cfg, &mut MatmulScratch::new())
}

/// [`qmatmul_scheduled`] with caller-owned buffers — the batched serving
/// hot path. Output is identical; only the allocation behaviour differs.
pub fn qmatmul_scheduled_with(
    inst: &MatmulInstance,
    epi: &Epilogue,
    cfg: &ScheduleConfig,
    scratch: &mut MatmulScratch,
) -> Vec<i32> {
    let wl = &inst.wl;
    let (m, n) = (wl.m, wl.n);
    debug_assert_eq!(inst.bias.len(), n);
    qmatmul_accumulate_with(wl, &inst.a, &inst.b, cfg, scratch);

    // fused epilogue + padded-INT4 packing, row-major
    let mut out = Vec::with_capacity(m * n.div_ceil(8));
    scratch.rowbuf.clear();
    scratch.rowbuf.resize(n, 0);
    for row in 0..m {
        for c in 0..n {
            scratch.rowbuf[c] = epi.apply(scratch.acc[row * n + c], inst.bias[c]);
        }
        pack_int4_padded_into(&scratch.rowbuf, &mut out);
    }
    out
}

/// The GEMM half of [`qmatmul_scheduled_with`]: run the blocked i32 GEMM,
/// leaving the raw `(m x n)` accumulator in the scratch
/// ([`MatmulScratch::accumulator`]) with no epilogue applied — the graph
/// executor's entry point, mirroring
/// [`crate::conv::qconv2d_accumulate_with`]. Operands are plain slices
/// because graph weights are plan-owned, not per-request instances.
pub fn qmatmul_accumulate_with(
    wl: &MatmulWorkload,
    a: &[i8],
    b: &[i8],
    cfg: &ScheduleConfig,
    scratch: &mut MatmulScratch,
) {
    let (m, n, k) = (wl.m, wl.n, wl.k);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);

    // pipelined microkernel, geometry steered by the tuned schedule
    // (clamped to cache-sane bounds, matching the conv executor's policy)
    let bm = cfg.block_m().clamp(8, 64);
    let bk = cfg.block_k().clamp(32, 128);
    let bn = cfg.block_n().clamp(8, 64).min(default_bn(n));
    scratch.acc.clear();
    scratch.acc.resize(m * n, 0);
    if let Some(cache) = &scratch.prepack {
        let fp = operand_fingerprint(b);
        let packed = cache.get_or_pack(fp, b, k, n, 0, n, bn, bk);
        gemm_i32_pipelined(a, &packed, &mut scratch.acc, m, n, 0, bm, &mut scratch.gemm.bufs);
    } else {
        let GemmScratch { bufs, packed } = &mut scratch.gemm;
        packed.pack_into(b, k, n, 0, n, bn, bk);
        gemm_i32_pipelined(a, packed, &mut scratch.acc, m, n, 0, bm, bufs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::unpack_int4;

    /// Independent scalar reference: the dumbest possible triple loop.
    fn matmul_reference(inst: &MatmulInstance, epi: &Epilogue) -> Vec<i32> {
        let wl = &inst.wl;
        let mut out = Vec::new();
        let mut row = vec![0i32; wl.n];
        for i in 0..wl.m {
            for j in 0..wl.n {
                let mut acc = 0i32;
                for kk in 0..wl.k {
                    acc += inst.a[i * wl.k + kk] as i32 * inst.b[kk * wl.n + j] as i32;
                }
                row[j] = epi.apply(acc, inst.bias[j]);
            }
            pack_int4_padded_into(&row, &mut out);
        }
        out
    }

    #[test]
    fn executor_matches_scalar_reference() {
        let wl = MatmulWorkload::new("t", 16, 24, 32);
        let inst = MatmulInstance::synthetic(&wl, 1);
        let epi = Epilogue::default();
        assert_eq!(qmatmul(&inst, &epi), matmul_reference(&inst, &epi));
    }

    #[test]
    fn scheduled_execution_is_numerics_invariant() {
        let wl = MatmulWorkload::new("s", 32, 16, 64);
        let inst = MatmulInstance::synthetic(&wl, 9);
        let epi = Epilogue { relu: true, requant_shift: 4 };
        let want = qmatmul(&inst, &epi);
        let mut scratch = MatmulScratch::new();
        for cfg in [
            ScheduleConfig::default(),
            ScheduleConfig::tvm_baseline(),
            ScheduleConfig { blk_row_warps: 1, warp_row_tiles: 1, chunk: 1, ..Default::default() },
            ScheduleConfig { blk_row_warps: 8, warp_row_tiles: 8, chunk: 8, ..Default::default() },
        ] {
            assert_eq!(qmatmul_scheduled(&inst, &epi, &cfg), want, "{cfg:?}");
            assert_eq!(
                qmatmul_scheduled_with(&inst, &epi, &cfg, &mut scratch),
                want,
                "scratch reuse, {cfg:?}"
            );
        }
    }

    #[test]
    fn scratch_reuse_across_mixed_shapes_is_numerics_invariant() {
        let epi = Epilogue::default();
        let mut scratch = MatmulScratch::new();
        let shapes = [
            MatmulWorkload::new("a", 16, 8, 32),
            MatmulWorkload::new("b", 8, 24, 64),
            MatmulWorkload::new("a2", 16, 8, 32),
        ];
        for (i, wl) in shapes.iter().enumerate() {
            let inst = MatmulInstance::synthetic(wl, 40 + i as u64);
            let fresh = qmatmul(&inst, &epi);
            let reused = qmatmul_scheduled_with(
                &inst,
                &epi,
                &ScheduleConfig::default(),
                &mut scratch,
            );
            assert_eq!(fresh, reused, "{}", wl.name);
        }
    }

    #[test]
    fn ragged_n_packs_with_zero_tail() {
        // n = 12 packs each row into 2 words, the second half-empty
        let wl = MatmulWorkload::new("r", 4, 12, 32);
        let inst = MatmulInstance::synthetic(&wl, 5);
        let out = qmatmul(&inst, &Epilogue::default());
        assert_eq!(out.len(), 4 * 2);
        for v in unpack_int4(&out) {
            assert!((-8..=7).contains(&v));
        }
    }

    #[test]
    fn bert_shapes_have_aligned_gemms() {
        // the zoo's bert_base shapes tile the raw GEMM exactly
        for (m, n, k) in [(1024, 768, 768), (1024, 3072, 768), (12288, 128, 64)] {
            let wl = MatmulWorkload::new("b", m, n, k);
            assert_eq!(wl.legality_gemm(), (m, n, k));
            assert_eq!(wl.gemm_n_padded(), n, "already atom-aligned");
            assert_eq!(wl.gemm_k_padded(), k.div_ceil(32) * 32);
            assert!(ScheduleConfig { blk_row_warps: 1, warp_row_tiles: 1, chunk: 1, ..Default::default() }
                .is_legal_for(m, n, k));
        }
    }

    #[test]
    fn ops_counts_macs_x2() {
        let wl = MatmulWorkload::new("o", 16, 8, 32);
        assert_eq!(Workload::ops(&wl), 2 * 16 * 8 * 32);
    }
}
