//! Measurement database: every (genotype, config, runtime) the tuner has
//! paid for. Guarantees the §4.1 rule that no configuration is measured
//! twice, and serves as the cost model's training set.

use std::collections::{HashMap, HashSet};

use crate::searchspace::{Genotype, ScheduleConfig};

/// Append-only store of every measurement a session has paid for,
/// deduplicated by genotype (§4.1's "only picks candidates that have not
/// been measured before").
#[derive(Debug, Default)]
pub struct MeasureDb {
    rows: Vec<(Genotype, ScheduleConfig, f64)>,
    seen: HashSet<Genotype>,
    index: HashMap<Genotype, usize>,
}

impl MeasureDb {
    /// An empty database.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one measurement. Returns false (and ignores the row) if the
    /// genotype was already measured — callers violating the no-remeasure
    /// rule are surfaced in tests via this signal.
    pub fn record(&mut self, g: Genotype, cfg: ScheduleConfig, runtime_us: f64) -> bool {
        if !self.seen.insert(g.clone()) {
            return false;
        }
        self.index.insert(g.clone(), self.rows.len());
        self.rows.push((g, cfg, runtime_us));
        true
    }

    /// Distinct configurations measured so far.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether nothing has been measured yet.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Whether `g` has already been measured.
    pub fn contains(&self, g: &Genotype) -> bool {
        self.seen.contains(g)
    }

    /// The set of measured genotypes — what explorers exclude from
    /// proposals.
    pub fn measured_set(&self) -> &HashSet<Genotype> {
        &self.seen
    }

    /// The measured set unioned with `extra` — the exclusion set a
    /// multi-fidelity round hands its explorer: candidates screened out
    /// by a cheap rung never entered the database, but must not be
    /// re-proposed either.
    pub fn measured_union(&self, extra: &HashSet<Genotype>) -> HashSet<Genotype> {
        self.seen.union(extra).cloned().collect()
    }

    /// The recorded runtime of `g`, if it was measured.
    pub fn runtime_of(&self, g: &Genotype) -> Option<f64> {
        self.index.get(g).map(|&i| self.rows[i].2)
    }

    /// Every `(genotype, config, runtime_us)` row, in measurement order.
    pub fn iter(&self) -> impl Iterator<Item = &(Genotype, ScheduleConfig, f64)> {
        self.rows.iter()
    }

    /// Best (config, runtime) so far.
    pub fn best(&self) -> Option<(ScheduleConfig, f64)> {
        self.rows
            .iter()
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .map(|(_, c, r)| (*c, *r))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(bits: &[u8]) -> Genotype {
        bits.to_vec()
    }

    #[test]
    fn rejects_duplicates() {
        let mut db = MeasureDb::new();
        assert!(db.record(g(&[1, 2]), ScheduleConfig::default(), 10.0));
        assert!(!db.record(g(&[1, 2]), ScheduleConfig::default(), 11.0));
        assert_eq!(db.len(), 1);
        assert_eq!(db.runtime_of(&g(&[1, 2])), Some(10.0));
    }

    #[test]
    fn best_tracks_minimum() {
        let mut db = MeasureDb::new();
        db.record(g(&[0]), ScheduleConfig::default(), 30.0);
        db.record(g(&[1]), ScheduleConfig::tvm_baseline(), 20.0);
        db.record(g(&[2]), ScheduleConfig::default(), 25.0);
        let (cfg, rt) = db.best().unwrap();
        assert_eq!(rt, 20.0);
        assert_eq!(cfg, ScheduleConfig::tvm_baseline());
    }

    #[test]
    fn empty_db_has_no_best() {
        assert!(MeasureDb::new().best().is_none());
    }

    #[test]
    fn measured_union_merges_without_mutating() {
        let mut db = MeasureDb::new();
        db.record(g(&[0]), ScheduleConfig::default(), 30.0);
        let extra: HashSet<Genotype> = [g(&[0]), g(&[1])].into_iter().collect();
        let union = db.measured_union(&extra);
        assert_eq!(union.len(), 2, "overlap counted once");
        assert!(union.contains(&g(&[0])) && union.contains(&g(&[1])));
        assert_eq!(db.measured_set().len(), 1, "db untouched");
    }
}
