//! Background re-tuning: the serve→tune side of the loop.
//!
//! `repro tune-net` closes tune→serve (schedules found offline are loaded
//! by the server); this module closes the other direction. An
//! [`OnlineTuner`] watches a live server's [`Metrics`](crate::serve::Metrics)
//! for request kinds that are **schedule-less** (served under the default
//! fallback because the registry has no entry) or **hot but under-tuned**
//! (a registry entry found with a smaller measurement budget than this
//! policy's), runs a bounded [`Session`] for each — on spare
//! [`MeasurePool`](crate::sim::MeasurePool) workers via
//! [`SessionBuilder::parallelism`](crate::tuner::SessionBuilder::parallelism)
//! — and publishes improved schedules through the server's hot-reload
//! path ([`ServeHandle::update_registry`], an atomic in-place edit of
//! the live registry, so concurrent [`ServeHandle::reload_registry`]
//! calls are merged with, never reverted by, a slow tuning cycle), and
//! workers pick them up at the next batch boundary with zero dropped
//! requests.
//!
//! Warm starts reuse tuning state the way the paper's transfer learning
//! does (§4.1 transfer across workloads): every finished retune's
//! [`SessionResult`] — which carries its `MeasureDb` and `History` — is
//! kept per kind, and the next retune of a *different* kind
//! `transfer_from`s the most recent one, so the cost model never starts
//! cold once the re-tuner has run anything.
//!
//! Two usage modes:
//!
//! * **Deterministic, caller-paced**: call [`OnlineTuner::run_cycle`]
//!   yourself (what the tests and `repro serve --retune` do). Same
//!   metrics + same seed → same published registry, cycle for cycle.
//! * **Background**: [`OnlineTuner::spawn`] moves the tuner onto a
//!   thread that runs a cycle every `interval`; stop and collect the
//!   cycle reports with [`RetunerHandle::stop`].
#![deny(missing_docs)]

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::registry::TunedEntry;
use crate::serve::{ClusterHandle, Metrics, RegistrySnapshot, ServeHandle};
use crate::workload::OpWorkload;
use crate::zoo;

use super::cache::CacheHandle;
use super::{Session, SessionResult};

/// When and how hard the online tuner retunes.
#[derive(Debug, Clone)]
pub struct RetunePolicy {
    /// A kind must have at least this many completed requests to be
    /// considered (1 = any observed kind qualifies).
    pub min_requests: u64,
    /// Measurement budget per retuning session — deliberately small
    /// next to the paper's offline 500: the re-tuner runs *beside*
    /// serving, and warm starts make small budgets productive.
    pub trials: usize,
    /// Worker threads each session measures candidate batches on (the
    /// "spare `MeasurePool` workers"); 1 = serial.
    pub jobs: usize,
    /// At most this many kinds are retuned per cycle, hottest first —
    /// the bound that keeps a cycle's wall-clock predictable.
    pub max_kinds_per_cycle: usize,
    /// Publish an already-tuned kind's new schedule only if the tuned
    /// runtime improves on the registry entry by at least this fraction
    /// (0.0 = publish any strict improvement). Untuned kinds always
    /// publish.
    pub min_improvement: f64,
    /// Base seed; each kind's session derives a deterministic seed from
    /// this, the kind name, and the cycle index.
    pub seed: u64,
    /// Exploration module, by registry name (same names as
    /// `repro tune --explorer`).
    pub explorer: String,
    /// Tune with successive halving ([`SessionBuilder::multi_fidelity`](
    /// crate::tuner::SessionBuilder::multi_fidelity)): cheap low-rep
    /// rungs screen a wide field and only distinctive survivors spend
    /// the session's `trials` budget — the right trade for a re-tuner
    /// running beside live serving.
    pub multi_fidelity: bool,
}

impl Default for RetunePolicy {
    fn default() -> Self {
        Self {
            min_requests: 1,
            trials: 64,
            jobs: 2,
            max_kinds_per_cycle: 2,
            min_improvement: 0.0,
            seed: 0,
            explorer: "diversity-aware".to_string(),
            multi_fidelity: false,
        }
    }
}

/// Why a kind was selected for retuning.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetuneReason {
    /// The registry has no entry — requests run under the default
    /// fallback schedule.
    Untuned,
    /// The registry entry exists but was found with a smaller
    /// measurement budget than this policy's, and the kind is hot.
    Hot,
}

/// One kind the planner decided to retune this cycle.
#[derive(Debug, Clone)]
pub struct RetuneTask {
    /// The request kind (== workload name).
    pub kind: String,
    /// Why it was picked.
    pub reason: RetuneReason,
    /// Completed requests observed for the kind at planning time.
    pub requests: u64,
}

/// What one kind's retuning session produced.
#[derive(Debug, Clone)]
pub struct RetuneOutcome {
    /// The request kind.
    pub kind: String,
    /// Why it was retuned.
    pub reason: RetuneReason,
    /// Best (simulated) runtime the bounded session found, microseconds.
    pub tuned_runtime_us: f64,
    /// The registry entry's runtime before this cycle, if any.
    pub previous_runtime_us: Option<f64>,
    /// Whether the result was good enough to publish.
    pub published: bool,
    /// Whether the session was served from the cross-session
    /// [`TuneCache`](crate::tuner::TuneCache) with zero measurements.
    pub cache_hit: bool,
}

/// Summary of one [`OnlineTuner::run_cycle`].
#[derive(Debug, Clone)]
pub struct CycleReport {
    /// Kinds the metrics had seen at planning time.
    pub kinds_observed: usize,
    /// Per-task outcomes, in execution order.
    pub outcomes: Vec<RetuneOutcome>,
    /// Registry snapshot version the cycle published, if any outcome
    /// published (one reload per cycle, not per kind).
    pub published_version: Option<u64>,
}

impl CycleReport {
    /// How many outcomes were published this cycle.
    pub fn published_count(&self) -> usize {
        self.outcomes.iter().filter(|o| o.published).count()
    }
}

/// The background re-tuner: watches serve metrics, runs bounded tuning
/// sessions, publishes improved schedules via registry hot-reload.
pub struct OnlineTuner {
    workloads: HashMap<String, OpWorkload>,
    /// Whole-network request kinds (`graph:<net>`) mapped to the member
    /// layer kinds they execute, one entry per unrolled layer — the
    /// planner folds graph traffic onto these so a hot graph retunes
    /// all of its layers jointly.
    graphs: HashMap<String, Vec<String>>,
    policy: RetunePolicy,
    /// Finished sessions by kind — the warm-start fuel (`MeasureDb` +
    /// `History` ride inside each [`SessionResult`]).
    priors: HashMap<String, SessionResult>,
    /// The kind most recently retuned (its session seeds the next
    /// kind's transfer).
    last_kind: Option<String>,
    /// Cross-session tune cache every retune session consults and
    /// updates (exact hits cost zero measurements — a restarted
    /// re-tuner never re-pays for shapes an earlier process tuned).
    cache: Option<CacheHandle>,
    cycle: u64,
}

impl OnlineTuner {
    /// A tuner that can resolve the given kinds to concrete workloads
    /// (any operator — the map values convert into [`OpWorkload`]).
    /// Kinds missing from the map are ignored by the planner (the server
    /// can serve kinds the tuner has no shape for), and so are workloads
    /// whose search space admits **no legal schedule** (possible for
    /// raw-legality matmuls): [`crate::tuner::Session`] would error on
    /// them, and one such kind must not abort a whole retune cycle — or
    /// kill a spawned re-tuner loop — every time it gets traffic, so
    /// they are dropped here, once, at construction.
    pub fn new<W: Into<OpWorkload>>(
        workloads: HashMap<String, W>,
        policy: RetunePolicy,
    ) -> Self {
        use crate::searchspace::{SearchSpace, SpaceOptions};
        let workloads = workloads
            .into_iter()
            .map(|(k, w)| (k, w.into()))
            .filter(|(_, w)| SearchSpace::for_workload(w, SpaceOptions::default()).has_legal())
            .collect();
        Self {
            workloads,
            graphs: HashMap::new(),
            policy,
            priors: HashMap::new(),
            last_kind: None,
            cache: None,
            cycle: 0,
        }
    }

    /// Consult and update a cross-session
    /// [`TuneCache`](crate::tuner::TuneCache) in every retune session:
    /// exact fingerprint hits publish with zero measurements, misses
    /// warm-start from their nearest anchored neighbor, and every
    /// cycle's winners are persisted for the next process.
    pub fn with_tune_cache(mut self, cache: CacheHandle) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Convenience: resolve kinds against every layer of the model
    /// [`zoo`] at the given batch size (what `repro serve --retune`
    /// uses — registry kinds written by `tune-net` are the zoo layers'
    /// namespaced `conv:*` / `matmul:*` kinds). Every network is also
    /// registered as a graph kind (`graph:<net>`, the kind
    /// [`crate::serve::Server::install_graph`] serves under), so
    /// whole-network traffic retunes member layers jointly.
    pub fn from_zoo(batch: usize, policy: RetunePolicy) -> Self {
        let workloads: HashMap<String, OpWorkload> = zoo::all_networks(batch)
            .into_iter()
            .flat_map(|n| n.layers)
            .map(|l| (l.workload.kind(), l.workload))
            .collect();
        let mut tuner = Self::new(workloads, policy);
        for net in zoo::all_networks(batch) {
            let members: Vec<String> = net
                .layers
                .iter()
                .flat_map(|l| (0..l.repeats).map(|_| l.workload.kind()))
                .collect();
            tuner.register_graph(format!("graph:{}", net.name), members);
        }
        tuner
    }

    /// Teach the planner that requests of `kind` (a `graph:<net>` kind)
    /// execute the given member layer kinds — one entry per executed
    /// layer, repeats included. [`OnlineTuner::plan`] then counts each
    /// graph request as traffic on every member, so one hot graph can
    /// pull all of its layers into joint retuning even though the
    /// member kinds never appear in the metrics themselves.
    pub fn register_graph(&mut self, kind: impl Into<String>, members: Vec<String>) {
        self.graphs.insert(kind.into(), members);
    }

    /// The policy this tuner runs under.
    pub fn policy(&self) -> &RetunePolicy {
        &self.policy
    }

    /// Decide what to retune, given live metrics and the current
    /// registry snapshot. Pure planning — no sessions run, nothing
    /// published.
    ///
    /// Eligible kinds: observed at least `min_requests` times, resolvable
    /// to a workload, not already retuned by this tuner, and either
    /// absent from the registry ([`RetuneReason::Untuned`]) or present
    /// with fewer trials than the policy budget ([`RetuneReason::Hot`]).
    /// Untuned kinds come first, then hotter kinds first; the list is
    /// truncated to `max_kinds_per_cycle`.
    ///
    /// Traffic on a registered graph kind (see
    /// [`OnlineTuner::register_graph`]) is folded onto its member layer
    /// kinds first: each `graph:<net>` request counts once per unrolled
    /// member layer, and sums with any direct per-op traffic the member
    /// also receives.
    pub fn plan(&self, metrics: &Metrics, snapshot: &RegistrySnapshot) -> Vec<RetuneTask> {
        let mut traffic: HashMap<String, u64> = HashMap::new();
        for kind in metrics.kinds() {
            let requests = metrics.summary(&kind).map(|s| s.count).unwrap_or(0);
            match self.graphs.get(&kind) {
                Some(members) => {
                    for member in members {
                        *traffic.entry(member.clone()).or_insert(0) += requests;
                    }
                }
                None => *traffic.entry(kind).or_insert(0) += requests,
            }
        }
        let mut tasks: Vec<RetuneTask> = Vec::new();
        for (kind, requests) in traffic {
            if requests < self.policy.min_requests {
                continue;
            }
            if !self.workloads.contains_key(&kind) {
                continue; // no shape to tune against
            }
            if self.priors.contains_key(&kind) {
                continue; // already retuned at this policy's budget
            }
            let reason = match snapshot.registry().get(&kind) {
                None => RetuneReason::Untuned,
                Some(entry) if entry.trials < self.policy.trials => RetuneReason::Hot,
                Some(_) => continue, // tuned at or beyond our budget
            };
            tasks.push(RetuneTask { kind, reason, requests });
        }
        // untuned first (they run under the fallback — the biggest win),
        // then by traffic, hottest first; kind name breaks ties so the
        // plan is deterministic regardless of metrics map order
        tasks.sort_by(|a, b| {
            let rank = |r: RetuneReason| match r {
                RetuneReason::Untuned => 0u8,
                RetuneReason::Hot => 1,
            };
            rank(a.reason)
                .cmp(&rank(b.reason))
                .then(b.requests.cmp(&a.requests))
                .then(a.kind.cmp(&b.kind))
        });
        tasks.truncate(self.policy.max_kinds_per_cycle);
        tasks
    }

    /// Deterministic per-session seed: base seed x kind x cycle.
    fn session_seed(&self, kind: &str) -> u64 {
        let mut h = DefaultHasher::new();
        self.policy.seed.hash(&mut h);
        kind.hash(&mut h);
        self.cycle.hash(&mut h);
        h.finish()
    }

    /// Run one full cycle against a live single server — see
    /// [`OnlineTuner::run_cycle_on`]; `ServeHandle` is just one
    /// [`RetuneSurface`].
    pub fn run_cycle(&mut self, handle: &ServeHandle) -> crate::Result<CycleReport> {
        self.run_cycle_on(handle)
    }

    /// Run one full cycle against any serving surface: plan, tune each
    /// picked kind with a bounded warm-started session, and publish
    /// every improvement as **one** atomic registry update per shard (so
    /// each snapshot version advances at most once per cycle). The
    /// publish goes through the surface's `update_registry` — an
    /// in-place edit of the *current* registry — so a reload that lands
    /// while the (slow) tuning phase runs is merged with, never reverted
    /// by, this cycle's winners.
    pub fn run_cycle_on<S: RetuneSurface>(&mut self, surface: &S) -> crate::Result<CycleReport> {
        let snapshot = surface.retune_snapshot();
        let metrics = surface.retune_metrics();
        let tasks = self.plan(&metrics, &snapshot);
        let kinds_observed = metrics.kinds().len();

        let mut winners: Vec<(String, TunedEntry)> = Vec::new();
        let mut outcomes = Vec::with_capacity(tasks.len());
        for task in tasks {
            let wl = self.workloads[&task.kind].clone();
            let mut builder = Session::for_workload(&wl)
                .trials(self.policy.trials)
                .seed(self.session_seed(&task.kind))
                .parallelism(self.policy.jobs)
                .explorer(&self.policy.explorer);
            // warm start from the most recent retune of another kind —
            // its MeasureDb rows join this session's training set
            if let Some(prev) = self.last_kind.as_ref().and_then(|k| self.priors.get(k)) {
                builder = builder.transfer_from(prev);
            }
            if let Some(cache) = &self.cache {
                builder = builder.tune_cache(cache.clone());
            }
            if self.policy.multi_fidelity {
                builder = builder.multi_fidelity();
            }
            let res = builder.run()?;

            let previous_runtime_us = snapshot.registry().get(&task.kind).map(|e| e.runtime_us);
            let published = match previous_runtime_us {
                None => true, // anything beats the untracked fallback
                Some(prev) => {
                    res.best.runtime_us < prev * (1.0 - self.policy.min_improvement)
                }
            };
            if published {
                winners.push((task.kind.clone(), res.registry_entry()));
            }
            outcomes.push(RetuneOutcome {
                kind: task.kind.clone(),
                reason: task.reason,
                tuned_runtime_us: res.best.runtime_us,
                previous_runtime_us,
                published,
                cache_hit: res.cache_hit(),
            });
            self.priors.insert(task.kind.clone(), res);
            self.last_kind = Some(task.kind);
        }

        let published_version =
            (!winners.is_empty()).then(|| surface.retune_publish(winners));
        self.cycle += 1;
        Ok(CycleReport { kinds_observed, outcomes, published_version })
    }

    /// Move the tuner onto a background thread that runs a cycle every
    /// `interval` until [`RetunerHandle::stop`] is called. A cycle that
    /// errors (e.g. an unknown explorer name in the policy) ends the
    /// loop; the error is surfaced by `stop`.
    pub fn spawn(mut self, handle: ServeHandle, interval: Duration) -> RetunerHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::spawn(move || {
            let mut reports = Vec::new();
            let mut error = None;
            while !stop2.load(Ordering::SeqCst) {
                match self.run_cycle(&handle) {
                    Ok(report) => reports.push(report),
                    Err(e) => {
                        error = Some(e);
                        break;
                    }
                }
                // sleep in small slices so stop() stays responsive
                let mut slept = Duration::ZERO;
                while slept < interval && !stop2.load(Ordering::SeqCst) {
                    let step = Duration::from_millis(5).min(interval - slept);
                    std::thread::sleep(step);
                    slept += step;
                }
            }
            (reports, error)
        });
        RetunerHandle { stop, thread: Some(thread) }
    }
}

/// Control handle for a spawned background re-tuner.
pub struct RetunerHandle {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<(Vec<CycleReport>, Option<anyhow::Error>)>>,
}

impl RetunerHandle {
    /// Signal the loop to stop, join the thread, and return every cycle
    /// report it produced (plus the error that ended the loop early, if
    /// any).
    pub fn stop(mut self) -> (Vec<CycleReport>, Option<anyhow::Error>) {
        self.stop.store(true, Ordering::SeqCst);
        match self.thread.take() {
            Some(t) => t.join().expect("retuner thread panicked"),
            None => (Vec::new(), None),
        }
    }
}

impl Drop for RetunerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// The serving surface one retune cycle drives: a registry snapshot to
/// plan against, a metrics view of the live traffic, and an atomic
/// publish path for the cycle's winners.
///
/// [`ServeHandle`] (one server) and [`ClusterHandle`] (a sharded
/// cluster, where metrics are the cross-shard rollup and a publish
/// reaches every shard's registry — staged copies of dead shards
/// included) both implement it, so [`OnlineTuner::run_cycle_on`] retunes
/// either deployment shape unchanged.
pub trait RetuneSurface {
    /// The registry snapshot tuning decisions are planned against.
    fn retune_snapshot(&self) -> Arc<RegistrySnapshot>;
    /// A snapshot of the traffic metrics observed so far.
    fn retune_metrics(&self) -> Metrics;
    /// Atomically merge the cycle's winners into the current registry;
    /// returns the resulting snapshot version (the newest across shards
    /// for a cluster).
    fn retune_publish(&self, winners: Vec<(String, TunedEntry)>) -> u64;
}

impl RetuneSurface for ServeHandle {
    fn retune_snapshot(&self) -> Arc<RegistrySnapshot> {
        self.registry_snapshot()
    }

    fn retune_metrics(&self) -> Metrics {
        self.metrics().clone()
    }

    fn retune_publish(&self, winners: Vec<(String, TunedEntry)>) -> u64 {
        self.update_registry(|registry| {
            for (kind, entry) in winners {
                registry.insert(&kind, entry);
            }
        })
    }
}

impl RetuneSurface for ClusterHandle {
    fn retune_snapshot(&self) -> Arc<RegistrySnapshot> {
        self.registry_snapshot()
    }

    fn retune_metrics(&self) -> Metrics {
        self.metrics()
    }

    fn retune_publish(&self, winners: Vec<(String, TunedEntry)>) -> u64 {
        let versions = self.update_registry(|registry| {
            for (kind, entry) in &winners {
                registry.insert(kind, entry.clone());
            }
        });
        versions.into_iter().flatten().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{ConvInstance, ConvWorkload};
    use crate::quant::Epilogue;
    use crate::registry::{ScheduleRegistry, TunedEntry};
    use crate::searchspace::ScheduleConfig;
    use crate::serve::{Cluster, ClusterConfig, Server, ServerConfig};

    /// Small workload whose legal space excludes the default schedule, so
    /// "the retuner published something better than the fallback" is
    /// observable in the served schedule itself.
    fn tiny() -> ConvWorkload {
        ConvWorkload::new("ot_tiny", 1, 8, 8, 32, 8)
    }

    fn drive(server: &Server, wl: &ConvWorkload, n: u64) {
        let epi = Epilogue::default();
        let rxs: Vec<_> = (0..n)
            .map(|s| server.submit(&wl.name, ConvInstance::synthetic(wl, s), epi).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
    }

    fn policy(trials: usize) -> RetunePolicy {
        RetunePolicy { trials, jobs: 1, seed: 5, ..Default::default() }
    }

    #[test]
    fn plan_prioritizes_untuned_then_hottest() {
        let a = ConvWorkload::new("pl_a", 1, 8, 8, 8, 8);
        let b = ConvWorkload::new("pl_b", 1, 8, 8, 8, 8);
        let c = ConvWorkload::new("pl_c", 1, 8, 8, 8, 8);
        let mut reg = ScheduleRegistry::new();
        // `a` is tuned but with a small budget (Hot candidate); b and c
        // are untuned
        reg.insert(
            "pl_a",
            TunedEntry {
                config: ScheduleConfig::default(),
                runtime_us: 50.0,
                trials: 8,
                explorer: "test".into(),
            },
        );
        let server = Server::from_registry(ServerConfig { workers: 1, ..Default::default() }, reg);
        drive(&server, &a, 6); // hottest
        drive(&server, &b, 4);
        drive(&server, &c, 2);

        let workloads: HashMap<String, ConvWorkload> = [a, b, c]
            .into_iter()
            .map(|w| (w.name.clone(), w))
            .collect();
        let tuner = OnlineTuner::new(
            workloads,
            RetunePolicy { max_kinds_per_cycle: 3, trials: 64, ..Default::default() },
        );
        let snap = server.registry_snapshot();
        let tasks = tuner.plan(server.metrics(), &snap);
        server.shutdown();

        // untuned (b, c — hotter b first) ahead of the hot-but-tuned a
        let order: Vec<(&str, RetuneReason)> =
            tasks.iter().map(|t| (t.kind.as_str(), t.reason)).collect();
        assert_eq!(
            order,
            vec![
                ("pl_b", RetuneReason::Untuned),
                ("pl_c", RetuneReason::Untuned),
                ("pl_a", RetuneReason::Hot),
            ]
        );
    }

    #[test]
    fn plan_skips_cold_unknown_and_converged_kinds() {
        let known = ConvWorkload::new("ps_known", 1, 8, 8, 8, 8);
        let mut reg = ScheduleRegistry::new();
        reg.insert(
            "ps_known",
            TunedEntry {
                config: ScheduleConfig::default(),
                runtime_us: 50.0,
                trials: 500, // >= policy budget: converged
                explorer: "test".into(),
            },
        );
        let server = Server::from_registry(ServerConfig { workers: 1, ..Default::default() }, reg);
        drive(&server, &known, 3);
        // a kind the tuner has no workload for
        let stranger = ConvWorkload::new("ps_stranger", 1, 6, 6, 8, 8);
        drive(&server, &stranger, 3);
        // a kind below the traffic threshold
        let cold = ConvWorkload::new("ps_cold", 1, 6, 6, 8, 8);
        drive(&server, &cold, 1);

        let mut workloads = HashMap::new();
        workloads.insert(known.name.clone(), known);
        workloads.insert(cold.name.clone(), cold);
        let tuner = OnlineTuner::new(
            workloads,
            RetunePolicy { min_requests: 2, trials: 64, ..Default::default() },
        );
        let snap = server.registry_snapshot();
        let tasks = tuner.plan(server.metrics(), &snap);
        server.shutdown();
        assert!(tasks.is_empty(), "{tasks:?}");
    }

    #[test]
    fn run_cycle_publishes_schedule_for_untuned_hot_kind() {
        let wl = tiny();
        let server = Server::start(ServerConfig { workers: 2, ..Default::default() });
        drive(&server, &wl, 6);
        assert_eq!(server.schedule_for(&wl.name), ScheduleConfig::default());
        assert_eq!(server.registry_version(), 1);

        let mut workloads = HashMap::new();
        workloads.insert(wl.name.clone(), wl.clone());
        let mut tuner = OnlineTuner::new(workloads, policy(48));
        let report = tuner.run_cycle(&server.handle()).unwrap();

        assert_eq!(report.outcomes.len(), 1);
        assert_eq!(report.outcomes[0].reason, RetuneReason::Untuned);
        assert!(report.outcomes[0].published);
        assert_eq!(report.published_version, Some(2));
        assert_eq!(server.registry_version(), 2);
        // the tiny workload's legal space excludes the default schedule,
        // so the published schedule is observably non-default...
        let published = server.schedule_for(&wl.name);
        assert_ne!(published, ScheduleConfig::default());
        // ...and the very next request executes under it
        let resp = server
            .submit(&wl.name, ConvInstance::synthetic(&wl, 99), Epilogue::default())
            .unwrap()
            .recv()
            .unwrap();
        assert_eq!(resp.schedule, published);
        assert_eq!(resp.registry_version, 2);
        server.shutdown();
    }

    #[test]
    fn run_cycle_on_cluster_merges_shard_traffic_and_publishes_everywhere() {
        let wl = tiny();
        let cluster = Cluster::start(ClusterConfig {
            shards: 2,
            shard: ServerConfig { workers: 1, ..Default::default() },
            hot_replicas: 2,
            hot_kinds: vec![wl.name.clone()],
            ..Default::default()
        });
        // hot kind: traffic round-robins across BOTH shards, so only the
        // merged cross-shard rollup sees the full request count
        let epi = Epilogue::default();
        let rxs: Vec<_> = (0..6u64)
            .map(|s| cluster.submit(&wl.name, ConvInstance::synthetic(&wl, s), epi).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        assert_eq!(cluster.metrics().summary(&wl.name).unwrap().count, 6);

        let mut workloads = HashMap::new();
        workloads.insert(wl.name.clone(), wl.clone());
        let mut tuner = OnlineTuner::new(workloads, policy(48));
        let report = tuner.run_cycle_on(&cluster.handle()).unwrap();
        assert_eq!(report.outcomes.len(), 1);
        assert!(report.outcomes[0].published);
        assert_eq!(report.published_version, Some(2), "both shards reload 1 -> 2");

        // the publish reached every shard: wherever the next request
        // routes, it executes under the tuned (non-default) schedule
        let published = cluster.registry_snapshot().schedule_for(&wl.name);
        assert_ne!(published, ScheduleConfig::default());
        let resp = cluster
            .submit(&wl.name, ConvInstance::synthetic(&wl, 99), epi)
            .unwrap()
            .recv()
            .unwrap();
        assert_eq!(resp.schedule, published);
        cluster.shutdown();
    }

    #[test]
    fn second_cycle_does_not_rechurn_the_same_kind() {
        let wl = tiny();
        let server = Server::start(ServerConfig { workers: 1, ..Default::default() });
        drive(&server, &wl, 4);
        let mut workloads = HashMap::new();
        workloads.insert(wl.name.clone(), wl.clone());
        let mut tuner = OnlineTuner::new(workloads, policy(32));
        let r1 = tuner.run_cycle(&server.handle()).unwrap();
        assert_eq!(r1.outcomes.len(), 1);
        // same traffic, second cycle: the kind now has a prior — no work,
        // no version bump
        let r2 = tuner.run_cycle(&server.handle()).unwrap();
        assert!(r2.outcomes.is_empty());
        assert_eq!(r2.published_version, None);
        assert_eq!(server.registry_version(), 2);
        server.shutdown();
    }

    #[test]
    fn cycles_are_deterministic_for_the_same_traffic_and_seed() {
        let wl = tiny();
        let run = || {
            let server = Server::start(ServerConfig { workers: 1, ..Default::default() });
            drive(&server, &wl, 4);
            let mut workloads = HashMap::new();
            workloads.insert(wl.name.clone(), wl.clone());
            let mut tuner = OnlineTuner::new(workloads, policy(32));
            let report = tuner.run_cycle(&server.handle()).unwrap();
            let schedule = server.schedule_for(&wl.name);
            server.shutdown();
            (report.outcomes[0].tuned_runtime_us, schedule)
        };
        assert_eq!(run(), run(), "same traffic + same seed must publish the same schedule");
    }

    #[test]
    fn spawned_retuner_publishes_and_stops_cleanly() {
        let wl = tiny();
        let server = Server::start(ServerConfig { workers: 2, ..Default::default() });
        drive(&server, &wl, 4);
        let mut workloads = HashMap::new();
        workloads.insert(wl.name.clone(), wl.clone());
        let tuner = OnlineTuner::new(workloads, policy(32));
        let retuner = tuner.spawn(server.handle(), Duration::from_millis(1));
        // wait until the first cycle's publish lands
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while server.registry_version() < 2 {
            assert!(std::time::Instant::now() < deadline, "retuner never published");
            std::thread::sleep(Duration::from_millis(2));
        }
        let (reports, error) = retuner.stop();
        assert!(error.is_none(), "{error:?}");
        assert!(!reports.is_empty());
        assert!(reports.iter().map(|r| r.published_count()).sum::<usize>() >= 1);
        assert_ne!(server.schedule_for(&wl.name), ScheduleConfig::default());
        server.shutdown();
    }

    #[test]
    fn untileable_workloads_are_dropped_at_construction() {
        // a raw-legality matmul no block_k divides would make Session
        // error; the planner must never select it, and a cycle over it
        // must be a clean no-op rather than an aborted loop
        use crate::workload::MatmulWorkload;
        let good = ConvWorkload::new("ot_good", 1, 8, 8, 8, 8);
        let mut workloads: HashMap<String, crate::workload::OpWorkload> = HashMap::new();
        workloads.insert("ot_good".into(), (&good).into());
        workloads.insert("ot_bad".into(), MatmulWorkload::new("ot_bad", 1024, 768, 48).into());
        let tuner = OnlineTuner::new(workloads, policy(16));
        assert!(tuner.workloads.contains_key("ot_good"));
        assert!(!tuner.workloads.contains_key("ot_bad"), "untileable kind must be dropped");
    }

    #[test]
    fn plan_folds_graph_traffic_onto_member_layers() {
        // two member layers; "conv:gt_a" appears twice in the graph
        // (a repeated block), so each graph request votes twice for it
        let a = ConvWorkload::new("gt_a", 1, 8, 8, 8, 8);
        let b = ConvWorkload::new("gt_b", 1, 8, 8, 8, 8);
        let mut workloads: HashMap<String, crate::workload::OpWorkload> = HashMap::new();
        workloads.insert("conv:gt_a".into(), (&a).into());
        workloads.insert("conv:gt_b".into(), (&b).into());
        let mut tuner = OnlineTuner::new(
            workloads,
            RetunePolicy { min_requests: 4, max_kinds_per_cycle: 4, ..Default::default() },
        );
        tuner.register_graph(
            "graph:gt_net",
            vec!["conv:gt_a".into(), "conv:gt_a".into(), "conv:gt_b".into()],
        );

        // 3 whole-network requests; the member kinds never hit the
        // metrics directly
        let metrics = Metrics::new();
        for _ in 0..3 {
            metrics.observe("graph:gt_net", 10.0, 100.0, 1, 0);
        }
        let server = Server::start(ServerConfig { workers: 1, ..Default::default() });
        let snap = server.registry_snapshot();
        let tasks = tuner.plan(&metrics, &snap);
        server.shutdown();

        // gt_a: 2 votes x 3 requests = 6; gt_b: 3 — below min_requests 4
        let order: Vec<(&str, u64)> =
            tasks.iter().map(|t| (t.kind.as_str(), t.requests)).collect();
        assert_eq!(order, vec![("conv:gt_a", 6)]);
        assert_eq!(tasks[0].reason, RetuneReason::Untuned);
    }

    #[test]
    fn graph_traffic_sums_with_direct_op_traffic() {
        let a = ConvWorkload::new("gs_a", 1, 8, 8, 8, 8);
        let mut workloads: HashMap<String, crate::workload::OpWorkload> = HashMap::new();
        workloads.insert("conv:gs_a".into(), (&a).into());
        let mut tuner = OnlineTuner::new(workloads, RetunePolicy::default());
        tuner.register_graph("graph:gs_net", vec!["conv:gs_a".into()]);

        let metrics = Metrics::new();
        metrics.observe("graph:gs_net", 10.0, 100.0, 1, 0);
        metrics.observe("graph:gs_net", 10.0, 100.0, 1, 0);
        metrics.observe("conv:gs_a", 10.0, 50.0, 1, 0);
        let server = Server::start(ServerConfig { workers: 1, ..Default::default() });
        let snap = server.registry_snapshot();
        let tasks = tuner.plan(&metrics, &snap);
        server.shutdown();
        assert_eq!(tasks.len(), 1);
        assert_eq!(tasks[0].kind, "conv:gs_a");
        assert_eq!(tasks[0].requests, 3, "graph votes and direct traffic must sum");
    }

    #[test]
    fn graph_traffic_retunes_members_and_plan_picks_them_up() {
        // end-to-end serve->tune->serve for a whole-network kind: only
        // graph requests flow, yet the cycle publishes schedules for the
        // member layers and the lazily recompiled GraphPlan uses them
        use crate::graph::{GraphInput, GraphTopology, GraphWeights};
        use crate::quant::RequantParams;

        let server = Server::start(ServerConfig { workers: 1, ..Default::default() });
        let mut topo = GraphTopology::new("gr_net");
        let mut members = Vec::new();
        for i in 0..2 {
            let wl = ConvWorkload::new(format!("gr_l{i}"), 1, 8, 8, 8, 8);
            members.push(crate::workload::OpWorkload::from(&wl).kind());
            topo.add_layer(wl);
        }
        let weights = GraphWeights::synthetic(&topo, 3);
        server.install_graph(topo.clone(), weights, RequantParams::default()).unwrap();
        let rxs: Vec<_> = (0..4u64)
            .map(|s| server.submit_graph("gr_net", GraphInput::synthetic(&topo, s)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        assert_eq!(server.graph_plan("gr_net").unwrap().tuned_nodes(), 0);

        let mut workloads: HashMap<String, crate::workload::OpWorkload> = HashMap::new();
        for (kind, node) in members.iter().zip(topo.nodes()) {
            workloads.insert(kind.clone(), node.workload.clone());
        }
        let mut tuner = OnlineTuner::new(workloads, policy(16));
        tuner.register_graph("graph:gr_net", members);
        let report = tuner.run_cycle(&server.handle()).unwrap();
        assert_eq!(report.outcomes.len(), 2);
        assert!(report.outcomes.iter().all(|o| o.published));
        assert_eq!(server.registry_version(), 2);
        // the next plan lookup recompiles against the published registry
        assert_eq!(server.graph_plan("gr_net").unwrap().tuned_nodes(), 2);
        server.shutdown();
    }

    #[test]
    fn shared_tune_cache_makes_the_second_retuner_free() {
        // two re-tuner "processes" sharing one cache: the first pays for
        // the tune; the second serves the same shape from the cache with
        // zero measurements and publishes the identical schedule
        let wl = tiny();
        let cache = crate::tuner::CacheHandle::in_memory();
        let run = |cache: crate::tuner::CacheHandle| {
            let server = Server::start(ServerConfig { workers: 1, ..Default::default() });
            drive(&server, &wl, 4);
            let mut workloads = HashMap::new();
            workloads.insert(wl.name.clone(), wl.clone());
            let mut tuner = OnlineTuner::new(workloads, policy(32)).with_tune_cache(cache);
            let report = tuner.run_cycle(&server.handle()).unwrap();
            let schedule = server.schedule_for(&wl.name);
            server.shutdown();
            (report.outcomes[0].clone(), schedule)
        };
        let (first, sched1) = run(cache.clone());
        assert!(!first.cache_hit);
        assert!(first.published);
        assert_eq!(cache.len(), 1);
        let (second, sched2) = run(cache.clone());
        assert!(second.cache_hit, "same fingerprint: served from the cache");
        assert!(second.published, "fresh server had no entry to beat");
        assert_eq!(second.tuned_runtime_us, first.tuned_runtime_us);
        assert_eq!(sched1, sched2);
    }

    #[test]
    fn multi_fidelity_policy_screens_before_spending() {
        let wl = tiny();
        let server = Server::start(ServerConfig { workers: 1, ..Default::default() });
        drive(&server, &wl, 4);
        let mut workloads = HashMap::new();
        workloads.insert(wl.name.clone(), wl.clone());
        let mut tuner = OnlineTuner::new(
            workloads,
            RetunePolicy { multi_fidelity: true, ..policy(32) },
        );
        let report = tuner.run_cycle(&server.handle()).unwrap();
        assert_eq!(report.outcomes.len(), 1);
        assert!(report.outcomes[0].published);
        // the session ran halving: its budget ledger shows cheap passes
        let res = tuner.priors.values().next().unwrap();
        let budget = res.budget().expect("multi-fidelity sessions carry a ledger");
        assert!(budget.low_total() > 0);
        assert!(budget.full_total() <= 32);
        assert!(!res.best.rungs.is_empty());
        server.shutdown();
    }

    #[test]
    fn from_zoo_resolves_tune_net_kinds() {
        // zoo kinds are namespaced per operator — exactly what tune-net
        // writes into the registry and what serve traffic routes on
        let tuner = OnlineTuner::from_zoo(1, RetunePolicy::default());
        assert!(tuner.workloads.contains_key("conv:resnet50_stage2"));
        assert!(tuner.workloads.contains_key("conv:mbv2_dw_28"));
        assert!(tuner.workloads.contains_key("conv:deeplab_d4"));
        assert!(tuner.workloads.contains_key("matmul:bert_ffn_up"));
        assert!(!tuner.workloads.contains_key("resnet50_stage2"));
    }
}
