//! Tuning-curve recording — the data behind Fig. 14 (best GFLOPS vs
//! number of trials).

use crate::searchspace::ScheduleConfig;

/// One measured trial.
#[derive(Debug, Clone)]
pub struct TrialRecord {
    /// 1-based trial index within the session.
    pub trial: usize,
    /// The schedule measured at this trial.
    pub config: ScheduleConfig,
    /// Its measured runtime, microseconds.
    pub runtime_us: f64,
    /// Best runtime seen up to and including this trial.
    pub best_so_far_us: f64,
    /// Throughput of the best-so-far schedule (GFLOPS, the paper's Fig. 14
    /// y-axis), derived from the workload's op count.
    pub best_gflops: f64,
}

/// A whole session's trial log.
#[derive(Debug, Clone)]
pub struct History {
    /// Self-reported name of the exploration module that drove the
    /// session.
    pub explorer: &'static str,
    records: Vec<TrialRecord>,
}

impl History {
    /// An empty log attributed to `explorer`.
    pub fn new(explorer: &'static str) -> Self {
        Self { explorer, records: Vec::new() }
    }

    /// Append one measured trial, updating the best-so-far curve.
    pub fn push(&mut self, config: ScheduleConfig, runtime_us: f64, workload_ops: u64) {
        let best = self
            .records
            .last()
            .map_or(runtime_us, |r| r.best_so_far_us.min(runtime_us));
        self.records.push(TrialRecord {
            trial: self.records.len() + 1,
            config,
            runtime_us,
            best_so_far_us: best,
            best_gflops: workload_ops as f64 / best / 1e3, // ops / us -> GFLOPS
        });
    }

    /// Trials recorded so far.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether no trial has been recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The full trial log, in measurement order.
    pub fn records(&self) -> &[TrialRecord] {
        &self.records
    }

    /// Best runtime after the first `n` trials (for curve comparisons).
    pub fn best_after(&self, n: usize) -> f64 {
        self.records
            .iter()
            .take(n)
            .map(|r| r.best_so_far_us)
            .fold(f64::INFINITY, f64::min)
    }

    /// The monotone best-so-far runtime curve.
    pub fn best_curve(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.best_so_far_us).collect()
    }

    /// The Fig. 14 series: (trial, best GFLOPS).
    pub fn gflops_curve(&self) -> Vec<(usize, f64)> {
        self.records.iter().map(|r| (r.trial, r.best_gflops)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_so_far_is_monotone_nonincreasing() {
        let mut h = History::new("test");
        let ops = 1_000_000u64;
        for rt in [50.0, 40.0, 60.0, 35.0, 80.0] {
            h.push(ScheduleConfig::default(), rt, ops);
        }
        assert_eq!(h.best_curve(), vec![50.0, 40.0, 40.0, 35.0, 35.0]);
        assert_eq!(h.len(), 5);
    }

    #[test]
    fn gflops_inverse_of_runtime() {
        let mut h = History::new("test");
        h.push(ScheduleConfig::default(), 10.0, 2_000_000);
        // 2e6 ops / 10 us = 200 ops/us -> 0.2 GFLOPS? No: ops/us = Mops/s
        // ... 2e6 ops in 1e-5 s = 2e11 ops/s = 200 GFLOPS
        assert!((h.records()[0].best_gflops - 200.0).abs() < 1e-9);
    }

    #[test]
    fn best_after_prefix() {
        let mut h = History::new("test");
        for rt in [90.0, 70.0, 30.0] {
            h.push(ScheduleConfig::default(), rt, 1);
        }
        assert_eq!(h.best_after(2), 70.0);
        assert_eq!(h.best_after(3), 30.0);
        assert_eq!(h.best_after(0), f64::INFINITY);
    }
}
