//! The fluent tuning-session API — the crate's front door.
//!
//! ```no_run
//! use tcconv::conv::ConvWorkload;
//! use tcconv::tuner::Session;
//!
//! let wl = ConvWorkload::resnet50_stage(2, 8);
//! let res = Session::for_workload(&wl)
//!     .trials(500)
//!     .explorer("diversity")
//!     .run()
//!     .unwrap();
//! println!("{} -> {:.2} us", res.best.config.brief(), res.best.runtime_us);
//! ```
//!
//! Candidate measurement — where tuning spends its wall-clock time — can
//! be fanned across a worker pool with [`SessionBuilder::parallelism`];
//! results are bit-identical to a serial run of the same seed:
//!
//! ```
//! use tcconv::conv::ConvWorkload;
//! use tcconv::tuner::Session;
//!
//! let wl = ConvWorkload::resnet50_stage(2, 8);
//! let res = Session::for_workload(&wl)
//!     .trials(32)
//!     .seed(7)
//!     .parallelism(2) // measure each proposal batch on 2 workers
//!     .run()
//!     .unwrap();
//! assert_eq!(res.best.trials_used, 32);
//! ```
//!
//! A [`SessionResult`] keeps the measurement database, so sessions chain
//! via [`SessionBuilder::transfer_from`] (the paper's cross-workload
//! transfer learning) and convert into
//! [`crate::registry::ScheduleRegistry`] entries via
//! [`SessionResult::registry_entry`] — the artifact the serving layer
//! loads.
#![deny(missing_docs)]

use crate::costmodel::{featurize, CostModel};
use crate::explore::{Explorer, ExplorerRegistry};
use crate::registry::{TunedEntry, REGISTRY_VERSION};
use crate::searchspace::{SearchSpace, SpaceOptions};
use crate::sim::{MeasureBudget, Measurer};
use crate::util::Rng;
use crate::workload::OpWorkload;

use super::cache::{CacheEntry, CacheHandle, Fingerprint};
use super::{HalvingOptions, History, MeasureDb, TuneResult, Tuner, TunerOptions};

/// Entry point for the fluent API.
pub struct Session;

impl Session {
    /// Start configuring a tuning session for one workload — any
    /// operator: a `&ConvWorkload`, a `&MatmulWorkload`, or an
    /// [`OpWorkload`] all convert.
    pub fn for_workload(wl: impl Into<OpWorkload>) -> SessionBuilder {
        SessionBuilder {
            wl: wl.into(),
            trials: 500,
            batch_size: 32,
            seed: 0,
            jobs: 1,
            space: SpaceOptions::default(),
            explorer: "diversity-aware".to_string(),
            registry: ExplorerRegistry::with_builtins(),
            measurer: None,
            model: None,
            prior: Vec::new(),
            cache: None,
            halving: None,
            budget: None,
        }
    }
}

/// Fluent configuration of one tuning session.
pub struct SessionBuilder {
    wl: OpWorkload,
    trials: usize,
    batch_size: usize,
    seed: u64,
    jobs: usize,
    space: SpaceOptions,
    explorer: String,
    registry: ExplorerRegistry,
    measurer: Option<Box<dyn Measurer>>,
    model: Option<Box<dyn CostModel>>,
    prior: Vec<(Vec<f64>, f64)>,
    cache: Option<CacheHandle>,
    halving: Option<HalvingOptions>,
    budget: Option<MeasureBudget>,
}

impl SessionBuilder {
    /// Total measurement budget (paper default: 500).
    pub fn trials(mut self, n: usize) -> Self {
        self.trials = n;
        self
    }

    /// Configs measured per round (paper default: 32).
    pub fn batch_size(mut self, n: usize) -> Self {
        self.batch_size = n;
        self
    }

    /// Seed for everything stochastic in the session: exploration,
    /// cost-model initialization, and the default measurer's simulated
    /// noise. Same seed, same session — serial or parallel.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Search-space shape (knob ranges / legality rules).
    pub fn space(mut self, space: SpaceOptions) -> Self {
        self.space = space;
        self
    }

    /// Measure each proposal batch on `n` worker threads (default 1 =
    /// serial). Parallel sessions reproduce serial sessions bit-for-bit:
    /// measurement noise is keyed per candidate and the pool merges
    /// results in candidate order (see [`crate::sim::pool`]).
    ///
    /// Applies to the *default* measurement substrate (the seeded T4
    /// simulator behind a [`crate::sim::ParallelMeasurer`]); an explicit
    /// [`SessionBuilder::measurer`] wins over this knob, since a custom
    /// substrate decides its own execution strategy via
    /// [`Measurer::measure_batch`](crate::sim::Measurer::measure_batch).
    pub fn parallelism(mut self, n: usize) -> Self {
        self.jobs = n.max(1);
        self
    }

    /// Select the exploration module by registry name (canonical name or
    /// alias, e.g. `"diversity"`, `"sa"`). Resolution happens in
    /// [`SessionBuilder::run`]; unknown names error there, listing the
    /// valid options.
    pub fn explorer(mut self, name: &str) -> Self {
        self.explorer = name.to_string();
        self
    }

    /// Swap the explorer registry (to add custom exploration modules).
    pub fn explorer_registry(mut self, registry: ExplorerRegistry) -> Self {
        self.registry = registry;
        self
    }

    /// Register one custom exploration module on this session's registry.
    pub fn register_explorer<F>(mut self, name: &str, factory: F) -> Self
    where
        F: Fn(&SearchSpace) -> Box<dyn Explorer> + 'static,
    {
        self.registry.register(name, factory);
        self
    }

    /// Measurement substrate (default: the noisy T4 simulator, seeded from
    /// this session's seed).
    pub fn measurer(mut self, m: Box<dyn Measurer>) -> Self {
        self.measurer = Some(m);
        self
    }

    /// Cost-model prototype (default: the GBT ranker). Prototypes are
    /// installed as-is; to reuse one prototype across several sessions,
    /// pass `proto.clone_model()` to each.
    pub fn model(mut self, m: Box<dyn CostModel>) -> Self {
        self.model = Some(m);
        self
    }

    /// Warm-start from a finished session on another workload: its
    /// measurements join this session's training set (featurized under the
    /// prior workload, whose context dims make transfer meaningful).
    /// Chainable — call once per prior session.
    pub fn transfer_from(mut self, prior: &SessionResult) -> Self {
        for (_, cfg, rt) in prior.db().iter() {
            self.prior.push((featurize(prior.workload(), cfg), *rt));
        }
        self
    }

    /// Consult and update a cross-session
    /// [`TuneCache`](crate::tuner::TuneCache) through `cache`. On an
    /// exact fingerprint hit (with the cached schedule still legal for
    /// this concrete shape) the session returns it with **zero
    /// measurements**; on a nearest-anchor miss the explorer is
    /// warm-started from the neighbor schedule's one-knob neighborhood
    /// and the cost model pretrains on the cache's accumulated rows.
    /// The session's own result is inserted and persisted on completion.
    pub fn tune_cache(mut self, cache: CacheHandle) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Tune with successive halving at the default
    /// [`HalvingOptions`]: cheap low-rep simulation rungs prune a wide
    /// candidate field, and only surviving distinctive candidates are
    /// measured at full fidelity (see [`Tuner::tune_halving`]).
    pub fn multi_fidelity(self) -> Self {
        self.halving(HalvingOptions::default())
    }

    /// Tune with successive halving at explicit knobs.
    pub fn halving(mut self, opts: HalvingOptions) -> Self {
        self.halving = Some(opts);
        self
    }

    /// Attach a [`MeasureBudget`] ledger: every low- and full-fidelity
    /// measurement this session performs is booked against it, per
    /// rung. Multi-fidelity sessions get a fresh ledger automatically;
    /// pass one explicitly to share it (or read it) from outside —
    /// it is also available on the result via [`SessionResult::budget`].
    pub fn budget(mut self, budget: MeasureBudget) -> Self {
        self.budget = Some(budget);
        self
    }

    /// Build the tuner and run the full session.
    pub fn run(self) -> crate::Result<SessionResult> {
        let Self {
            wl,
            trials,
            batch_size,
            seed,
            jobs,
            space,
            explorer,
            registry,
            measurer,
            model,
            mut prior,
            cache,
            halving,
            budget,
        } = self;
        let search_space = SearchSpace::for_workload(&wl, space);
        // untileable workloads (possible since raw-legality matmuls: a
        // shape no block configuration divides) error up front instead of
        // spending the whole trial budget rejection-sampling an empty
        // legal space and publishing an infeasible "best"
        if !search_space.has_legal() {
            anyhow::bail!(
                "workload '{}' admits no legal schedule: its legality GEMM {:?} \
                 is not divisible by any block configuration",
                crate::workload::Workload::kind(&wl),
                crate::workload::Workload::legality_gemm(&wl),
            );
        }
        // every multi-fidelity session carries a ledger, caller-shared or not
        let budget = budget.or_else(|| halving.map(|_| MeasureBudget::new()));

        // consult the cross-session cache before spending anything
        let fp = Fingerprint::of(&wl);
        let mut warm_seeds = Vec::new();
        if let Some(cache) = &cache {
            if let Some(entry) = cache.lookup(&fp) {
                let (m, n, k) = crate::workload::Workload::legality_gemm(&wl);
                // two concrete shapes can share an anchor bucket, so the
                // exact hit still proves the schedule tiles *this* shape
                if entry.config.is_legal_for(m, n, k) {
                    let best = TuneResult {
                        config: entry.config,
                        runtime_us: entry.runtime_us,
                        // provenance of the accumulated spend, not of this
                        // session: zero *new* measurements were taken (the
                        // attached budget ledger stays at zero to prove it)
                        trials_used: entry.trials,
                        history: History::new("tune-cache"),
                        rungs: Vec::new(),
                    };
                    return Ok(SessionResult {
                        workload: wl,
                        best,
                        db: MeasureDb::new(),
                        explorer_name: "tune-cache".to_string(),
                        budget,
                        cache_hit: true,
                    });
                }
            }
            // miss: warm-start from the nearest anchored neighbor's
            // schedule (its one-knob neighborhood leads the first round)
            // and pretrain the cost model on everything the cache knows
            if let Some((donor, _)) = cache.nearest(&fp) {
                let mut rng = Rng::new(seed ^ 0x5EED);
                warm_seeds = crate::explore::neighborhood(
                    &search_space,
                    &donor.config,
                    batch_size,
                    &mut rng,
                );
            }
            prior.extend(cache.pretrain_rows());
        }

        // provenance: the canonical registry name this session selected
        // (Explorer::name() may differ for custom modules)
        let explorer_name = registry
            .resolve(&explorer)
            .unwrap_or(explorer.as_str())
            .to_string();
        let explorer = registry.build(&explorer, &search_space)?;
        let opts = TunerOptions {
            n_trials: trials,
            batch_size,
            explorer: crate::explore::ExplorerKind::default(), // unused: explorer is prebuilt
            seed,
            space,
            measurer: measurer.unwrap_or_else(|| {
                let sim = crate::sim::Simulator { seed, ..Default::default() };
                if jobs > 1 {
                    crate::sim::ParallelMeasurer::boxed(sim, jobs)
                } else {
                    sim.into_measurer()
                }
            }),
            model,
        };
        // assemble directly with the space already built for the registry
        // lookup (Tuner::with_explorer would re-derive the identical one)
        let mut tuner = Tuner::assemble(wl.clone(), search_space, explorer, opts);
        if let Some(b) = &budget {
            tuner.attach_budget(b.clone());
        }
        if !warm_seeds.is_empty() {
            tuner.set_warm_seeds(warm_seeds);
        }
        if !prior.is_empty() {
            tuner.set_prior(prior);
        }
        let best = match halving {
            Some(opts) => tuner.tune_halving(opts),
            None => tuner.tune(),
        };
        let db = tuner.into_db();
        // write back: file this session's result under its fingerprint
        // (kept only if it beats the bucket's best) and persist
        if let Some(cache) = &cache {
            cache.insert(CacheEntry {
                workload: wl.clone(),
                config: best.config,
                runtime_us: best.runtime_us,
                trials: best.trials_used,
                fidelity: if halving.is_some() { "multi" } else { "flat" }.to_string(),
                seed,
                registry_version: REGISTRY_VERSION,
            });
            cache.persist()?;
        }
        Ok(SessionResult { workload: wl, best, db, explorer_name, budget, cache_hit: false })
    }
}

/// Outcome of one tuning session: the best schedule plus everything a
/// follow-up session (transfer) or a deployment (registry entry) needs.
pub struct SessionResult {
    workload: OpWorkload,
    /// The best schedule found and the full tuning history.
    pub best: TuneResult,
    db: MeasureDb,
    /// Canonical registry name the session's explorer was selected by.
    explorer_name: String,
    budget: Option<MeasureBudget>,
    cache_hit: bool,
}

impl SessionResult {
    /// The workload this session tuned.
    pub fn workload(&self) -> &OpWorkload {
        &self.workload
    }

    /// The measurement-budget ledger this session booked against, if one
    /// was attached (always present for multi-fidelity sessions). On a
    /// cache hit the ledger is untouched — zero of everything.
    pub fn budget(&self) -> Option<&MeasureBudget> {
        self.budget.as_ref()
    }

    /// Whether the result was served from the
    /// [`TuneCache`](crate::tuner::TuneCache) with zero measurements.
    pub fn cache_hit(&self) -> bool {
        self.cache_hit
    }

    /// The namespaced registry kind of the tuned workload (`conv:<name>`
    /// / `matmul:<name>`) — the key to insert
    /// [`SessionResult::registry_entry`] under.
    pub fn kind(&self) -> String {
        self.workload.kind()
    }

    /// Every measurement the session paid for (transfer-learning fuel).
    pub fn db(&self) -> &MeasureDb {
        &self.db
    }

    /// The registry name this session's exploration module was selected
    /// by (provenance for the serve-time artifact).
    pub fn explorer_name(&self) -> &str {
        &self.explorer_name
    }

    /// This session's result as a [`crate::registry::ScheduleRegistry`]
    /// entry, keyed by the workload name at insert time.
    pub fn registry_entry(&self) -> TunedEntry {
        TunedEntry {
            config: self.best.config,
            runtime_us: self.best.runtime_us,
            trials: self.best.trials_used,
            explorer: self.explorer_name.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvWorkload;
    use crate::explore::RandomSearch;
    use crate::sim::{GpuSpec, SimMeasurer, Simulator};
    use crate::workload::MatmulWorkload;

    /// Small real workload whose legal space excludes the default
    /// schedule (gemm N = 8 forces 8-wide block columns), so every tuned
    /// config is observably non-default.
    fn tiny() -> ConvWorkload {
        ConvWorkload::new("tiny_session", 1, 8, 8, 32, 8)
    }

    #[test]
    fn session_matches_equivalent_tuner() {
        let wl = ConvWorkload::resnet50_stage(3, 8);
        let session = Session::for_workload(&wl)
            .trials(64)
            .seed(11)
            .explorer("diversity")
            .measurer(SimMeasurer::boxed(Simulator { seed: 11, ..Default::default() }))
            .run()
            .unwrap();
        let mut tuner = Tuner::new(
            &wl,
            TunerOptions {
                n_trials: 64,
                seed: 11,
                measurer: Simulator { seed: 11, ..Default::default() }.into_measurer(),
                ..Default::default()
            },
        );
        let direct = tuner.tune();
        assert_eq!(session.best.config, direct.config);
        assert_eq!(session.best.runtime_us, direct.runtime_us);
        assert_eq!(session.db().len(), 64);
    }

    #[test]
    fn parallel_session_reproduces_serial_session() {
        // end-to-end determinism across the whole Session pipeline: the
        // parallelism knob must change wall-clock only, never the result
        let wl = ConvWorkload::resnet50_stage(3, 8);
        let run = |jobs: usize| {
            Session::for_workload(&wl)
                .trials(96)
                .seed(21)
                .parallelism(jobs)
                .run()
                .unwrap()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.best.config, parallel.best.config);
        assert_eq!(serial.best.runtime_us, parallel.best.runtime_us);
        let a: Vec<f64> =
            serial.best.history.records().iter().map(|r| r.runtime_us).collect();
        let b: Vec<f64> =
            parallel.best.history.records().iter().map(|r| r.runtime_us).collect();
        assert_eq!(a, b, "identical measurement sequence, trial-for-trial");
        // explicit measurer wins over the parallelism knob (documented)
        let explicit = Session::for_workload(&wl)
            .trials(64)
            .seed(21)
            .parallelism(8)
            .measurer(SimMeasurer::boxed(Simulator { seed: 21, ..Default::default() }))
            .run()
            .unwrap();
        assert_eq!(explicit.db().len(), 64);
    }

    #[test]
    fn unknown_explorer_name_errors_with_options() {
        let err = Session::for_workload(&tiny())
            .explorer("genetic")
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("genetic"), "{err}");
        assert!(err.contains("diversity-aware"), "{err}");
    }

    #[test]
    fn custom_explorer_runs_by_name() {
        let res = Session::for_workload(&tiny())
            .trials(32)
            .register_explorer("my-random", |s: &SearchSpace| {
                Box::new(RandomSearch::new(s.clone())) as Box<dyn Explorer>
            })
            .explorer("my-random")
            .measurer(Simulator::noiseless(GpuSpec::t4()).into_measurer())
            .run()
            .unwrap();
        assert_eq!(res.best.history.explorer, "random");
        // provenance records the registry name the session selected, not
        // the module's self-reported name
        assert_eq!(res.explorer_name(), "my-random");
        assert_eq!(res.registry_entry().explorer, "my-random");
        assert!(res.best.runtime_us.is_finite());
    }

    #[test]
    fn transfer_from_feeds_prior_measurements() {
        let src_wl = ConvWorkload::resnet50_stage(2, 8);
        let dst_wl = ConvWorkload::resnet50_stage(3, 8);
        let src = Session::for_workload(&src_wl)
            .trials(64)
            .seed(5)
            .measurer(Simulator { seed: 5, ..Default::default() }.into_measurer())
            .run()
            .unwrap();
        let warm = Session::for_workload(&dst_wl)
            .trials(64)
            .seed(5)
            .measurer(Simulator { seed: 5, ..Default::default() }.into_measurer())
            .transfer_from(&src)
            .run()
            .unwrap();
        // transfer only changes guidance, never the accounting
        assert_eq!(warm.db().len(), 64);
        assert!(warm.best.runtime_us <= warm.best.history.best_after(64) * 1.0001);
    }

    #[test]
    fn matmul_session_tunes_and_transfers_from_conv() {
        // the tentpole path: a conv session's measurements warm-start a
        // matmul session through the shared feature space, and the matmul
        // result is a deployable registry entry under a matmul: kind
        let conv = ConvWorkload::resnet50_stage(3, 8);
        let src = Session::for_workload(&conv)
            .trials(48)
            .seed(4)
            .measurer(Simulator { seed: 4, ..Default::default() }.into_measurer())
            .run()
            .unwrap();
        let mm = MatmulWorkload::new("bert_ffn_up_t", 1024, 3072, 768);
        let res = Session::for_workload(&mm)
            .trials(48)
            .seed(4)
            .measurer(Simulator { seed: 4, ..Default::default() }.into_measurer())
            .transfer_from(&src)
            .run()
            .unwrap();
        assert!(res.best.runtime_us.is_finite());
        assert_eq!(res.db().len(), 48);
        assert_eq!(res.kind(), "matmul:bert_ffn_up_t");
        assert_eq!(res.workload().name(), "bert_ffn_up_t");
        let entry = res.registry_entry();
        assert_eq!(entry.config, res.best.config);
        // the tuned schedule tiles the raw GEMM exactly
        assert!(entry.config.is_legal_for(1024, 3072, 768));
    }

    #[test]
    fn untileable_workload_errors_instead_of_tuning() {
        // raw-legality matmul with K = 48: no block_k divides it, so the
        // session must refuse up front — not burn 500 trials rejection-
        // sampling an empty legal space and publish an infeasible best
        let err = Session::for_workload(&MatmulWorkload::new("untileable", 1024, 768, 48))
            .trials(500)
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("matmul:untileable"), "{err}");
        assert!(err.contains("no legal schedule"), "{err}");
    }

    #[test]
    fn cache_hit_serves_with_zero_measurements() {
        let wl = ConvWorkload::resnet50_stage(3, 8);
        let cache = crate::tuner::CacheHandle::in_memory();
        let cold = Session::for_workload(&wl)
            .trials(48)
            .seed(9)
            .measurer(Simulator { seed: 9, ..Default::default() }.into_measurer())
            .tune_cache(cache.clone())
            .run()
            .unwrap();
        assert!(!cold.cache_hit());
        assert_eq!(cache.len(), 1, "cold result filed under its fingerprint");

        // same shape, different seed: exact hit, zero measurements —
        // proven by the attached ledger, not inferred from timing
        let budget = MeasureBudget::new();
        let warm = Session::for_workload(&wl)
            .trials(48)
            .seed(10)
            .measurer(Simulator { seed: 10, ..Default::default() }.into_measurer())
            .tune_cache(cache.clone())
            .budget(budget.clone())
            .run()
            .unwrap();
        assert!(warm.cache_hit());
        assert_eq!(warm.best.config, cold.best.config);
        assert_eq!(warm.best.runtime_us, cold.best.runtime_us);
        assert_eq!(warm.best.trials_used, cold.best.trials_used, "provenance of the spend");
        assert_eq!(budget.full_total() + budget.low_total(), 0);
        assert!(warm.db().is_empty());
        assert_eq!(warm.explorer_name(), "tune-cache");
        assert_eq!(warm.registry_entry().explorer, "tune-cache");
    }

    #[test]
    fn near_miss_warm_starts_from_the_nearest_neighbor() {
        // 64-channel donor, 128-channel probe: different anchor buckets
        // (no exact hit), but every donor-legal schedule tiles the probe
        // too, so the donor's best config leads the probe's first round
        let donor_wl = ConvWorkload::new("warm_donor", 8, 28, 28, 64, 64);
        let probe_wl = ConvWorkload::new("warm_probe", 8, 28, 28, 128, 128);
        let cache = crate::tuner::CacheHandle::in_memory();
        let donor = Session::for_workload(&donor_wl)
            .trials(48)
            .seed(2)
            .measurer(Simulator { seed: 2, ..Default::default() }.into_measurer())
            .tune_cache(cache.clone())
            .run()
            .unwrap();
        let probe = Session::for_workload(&probe_wl)
            .trials(32)
            .seed(2)
            .measurer(Simulator { seed: 2, ..Default::default() }.into_measurer())
            .tune_cache(cache.clone())
            .run()
            .unwrap();
        assert!(!probe.cache_hit(), "different anchor bucket is a miss");
        // replay the session's warm-seed computation: the first trial is
        // the first of the donor schedule's one-knob neighborhood
        let space = SearchSpace::for_workload(&probe_wl, SpaceOptions::default());
        let mut rng = crate::util::Rng::new(2 ^ 0x5EED);
        let seeds = crate::explore::neighborhood(&space, &donor.best.config, 32, &mut rng);
        assert!(!seeds.is_empty(), "donor schedule encodes into the probe's space");
        assert_eq!(probe.best.history.records()[0].config, space.decode(&seeds[0]));
        assert_eq!(cache.len(), 2, "the probe's own result was filed too");
    }

    #[test]
    fn registry_entry_reflects_best() {
        let res = Session::for_workload(&tiny())
            .trials(64)
            .seed(3)
            .measurer(Simulator::noiseless(GpuSpec::t4()).into_measurer())
            .run()
            .unwrap();
        let entry = res.registry_entry();
        assert_eq!(entry.config, res.best.config);
        assert_eq!(entry.runtime_us, res.best.runtime_us);
        assert_eq!(entry.trials, res.best.trials_used);
        assert_eq!(entry.explorer, "diversity-aware");
        // the tiny workload's legal space excludes the default schedule
        assert_ne!(entry.config, crate::searchspace::ScheduleConfig::default());
    }
}
