//! Tuner orchestration: the measure→train→explore loop of AutoTVM with the
//! paper's batch discipline (§4.1): batches of 32 configs measured per
//! round (top-31 model picks + 1 random), the cost model retrained on all
//! measurements after each round, and a database guaranteeing no config is
//! ever measured twice.
//!
//! Every pluggable stage sits behind a trait: exploration
//! ([`crate::explore::Explorer`], resolved by name through
//! [`crate::explore::ExplorerRegistry`]), cost modelling
//! ([`crate::costmodel::CostModel`]) and measurement
//! ([`crate::sim::Measurer`]). [`Session`] is the fluent front door that
//! wires them together and hands the result to the
//! [`crate::registry::ScheduleRegistry`] serving loads.
//!
//! Measurement — where all the wall-clock time goes — is issued per
//! *round*, not per candidate: [`Tuner::step`] hands the whole proposal
//! batch to [`Measurer::measure_batch`], so a parallel substrate
//! ([`crate::sim::ParallelMeasurer`], selected by
//! [`SessionBuilder::parallelism`] or `repro tune --jobs n`) fans the round
//! across a worker pool while the results stay in candidate order —
//! parallel and serial sessions are bit-for-bit identical.
//!
//! Tuning also runs *online*: [`online::OnlineTuner`] watches a live
//! [`crate::serve::Server`]'s metrics for hot or schedule-less request
//! kinds, retunes them with bounded warm-started sessions, and publishes
//! the winners through the server's registry hot-reload path.
//!
//! Measurement *spend* has two levers beyond parallelism. Within a
//! session, [`Tuner::tune_halving`] replaces the flat
//! measure-everything-fully loop with successive halving: a wide
//! candidate field is pruned through cheap low-rep simulation rungs
//! ([`Fidelity::Low`]) and only the surviving distinctive candidates pay
//! for full-fidelity measurement — every sim and full pass booked, per
//! rung, in a [`MeasureBudget`] ledger. Across sessions, the
//! [`cache::TuneCache`] persists tuned schedules keyed by an anchored
//! problem fingerprint, so a repeat shape costs zero measurements and a
//! near-miss warm-starts from its neighbor's schedule.

pub mod cache;
mod db;
mod history;
pub mod online;
mod session;

pub use cache::{CacheEntry, CacheHandle, Fingerprint, TuneCache, TUNE_CACHE_VERSION};
pub use db::MeasureDb;
pub use history::{History, TrialRecord};
pub use session::{Session, SessionBuilder, SessionResult};

// Re-export the measurement seam here too: tuning code is its main client.
pub use crate::sim::{
    CachedMeasurer, Fidelity, MeasureBudget, Measurer, ParallelMeasurer, RungCounts, SimMeasurer,
};

use std::collections::HashSet;

use crate::costmodel::{featurize, CostModel, Gbt, GbtParams};
use crate::explore::{Explorer, ExplorerKind};
use crate::searchspace::{Genotype, ScheduleConfig, SearchSpace, SpaceOptions};
use crate::sim::Simulator;
use crate::util::Rng;
use crate::workload::{OpWorkload, Workload};

/// Tuning-session options (§4.1 defaults).
pub struct TunerOptions {
    /// Total real-measurement budget ("500 trials" in the paper).
    pub n_trials: usize,
    /// Configs measured per round (31 model picks + 1 random).
    pub batch_size: usize,
    /// Builtin exploration module ([`Tuner::with_explorer`] call sites
    /// ignore this and supply a prebuilt one).
    pub explorer: ExplorerKind,
    /// Search-space shape (knob ranges / legality rules).
    pub space: SpaceOptions,
    /// Seed for everything stochastic in the session.
    pub seed: u64,
    /// Measurement substrate (replaces the old concrete `simulator` field;
    /// default: the noisy T4 simulator behind a [`SimMeasurer`]).
    pub measurer: Box<dyn Measurer>,
    /// Cost-model prototype; `None` = the GBT ranker seeded from `seed`.
    pub model: Option<Box<dyn CostModel>>,
}

impl Default for TunerOptions {
    fn default() -> Self {
        Self {
            n_trials: 500,
            batch_size: 32,
            explorer: ExplorerKind::DiversityAware,
            space: SpaceOptions::default(),
            seed: 0,
            measurer: Box::new(SimMeasurer::default()),
            model: None,
        }
    }
}

/// Successive-halving knobs for [`Tuner::tune_halving`].
#[derive(Debug, Clone, Copy)]
pub struct HalvingOptions {
    /// Candidates entering each round's first rung; `0` = 8x the
    /// session batch size (the halving advantage comes from screening a
    /// much wider field than a flat round could afford to measure).
    pub field: usize,
    /// Cull factor per rung: each rung keeps `ceil(entrants / eta)`.
    pub eta: usize,
    /// Cheap simulation rungs before the full-fidelity rung. Rung `r`
    /// measures at [`Fidelity::Low`]`(eta^r)` — later rungs average
    /// more reps, so the noise shrinks as the stakes rise.
    pub low_rungs: usize,
}

impl Default for HalvingOptions {
    fn default() -> Self {
        Self { field: 0, eta: 4, low_rungs: 2 }
    }
}

/// One rung of one successive-halving round: who entered, at what
/// fidelity, and who survived (in rank order). Equal seeds must replay
/// equal records bit-for-bit — the multi-fidelity determinism invariant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RungRecord {
    /// Halving round this rung belongs to.
    pub round: usize,
    /// Global rung index — the row key into
    /// [`MeasureBudget::rungs`]' ledger.
    pub rung: usize,
    /// Fidelity every entrant was measured at.
    pub fidelity: Fidelity,
    /// Candidates measured in this rung.
    pub entrants: usize,
    /// Genotypes promoted to the next rung (for the final full rung:
    /// the candidates actually measured), best-ranked first.
    pub survivors: Vec<Genotype>,
}

/// Best schedule found by a tuning session.
#[derive(Debug, Clone)]
pub struct TuneResult {
    /// The best schedule found.
    pub config: ScheduleConfig,
    /// Its measured (simulated) runtime, microseconds.
    pub runtime_us: f64,
    /// Measurements actually spent (≤ `n_trials`; less if the legal
    /// space was exhausted). Only *full-fidelity* measurements count —
    /// low-fidelity screening passes are tracked in the
    /// [`MeasureBudget`] ledger, not here.
    pub trials_used: usize,
    /// Full per-trial log (Fig. 14's tuning curve).
    pub history: History,
    /// Per-rung screening log ([`Tuner::tune_halving`] only; empty for
    /// flat sessions).
    pub rungs: Vec<RungRecord>,
}

/// One tuning session over one workload (any operator). Every
/// collaborator is a trait object — no concrete model or measurement
/// substrate appears in the fields.
pub struct Tuner {
    wl: OpWorkload,
    space: SearchSpace,
    explorer: Box<dyn Explorer>,
    model: Box<dyn CostModel>,
    db: MeasureDb,
    measurer: Box<dyn Measurer>,
    rng: Rng,
    n_trials: usize,
    batch_size: usize,
    /// Transfer-learning prior: (features, runtime) rows from other
    /// workloads, mixed into every retraining set. The feature vector
    /// includes workload-context dims, so one model ranks across convs
    /// (AutoTVM "accelerate[s] the process using transfer learning").
    prior: Vec<(Vec<f64>, f64)>,
    /// Ledger every measurement is booked against (when attached); the
    /// tuner advances its rung pointer so rows attribute per rung.
    budget: Option<MeasureBudget>,
    /// Genotypes injected ahead of the explorer's first proposals — the
    /// cache warm start (a nearest-anchor schedule's neighborhood).
    warm_seeds: Vec<Genotype>,
}

impl Tuner {
    /// Assemble a tuner for one workload from options (builds the search
    /// space and the `opts.explorer` module; [`Session`] is the
    /// higher-level front door).
    pub fn new(wl: impl Into<OpWorkload>, opts: TunerOptions) -> Self {
        let wl = wl.into();
        let space = SearchSpace::for_workload(&wl, opts.space);
        let explorer = opts.explorer.build(&space);
        Self::assemble(wl, space, explorer, opts)
    }

    /// Construct with a caller-built explorer (how [`Session`] plugs in
    /// registry-resolved or custom exploration modules); `opts.explorer`
    /// is ignored.
    pub fn with_explorer(
        wl: impl Into<OpWorkload>,
        opts: TunerOptions,
        explorer: Box<dyn Explorer>,
    ) -> Self {
        let wl = wl.into();
        let space = SearchSpace::for_workload(&wl, opts.space);
        Self::assemble(wl, space, explorer, opts)
    }

    fn assemble(
        wl: OpWorkload,
        space: SearchSpace,
        explorer: Box<dyn Explorer>,
        opts: TunerOptions,
    ) -> Self {
        let TunerOptions { n_trials, batch_size, seed, measurer, model, .. } = opts;
        let model = model
            .unwrap_or_else(|| Box::new(Gbt::new(GbtParams { seed, ..Default::default() })));
        Self {
            wl,
            space,
            explorer,
            model,
            db: MeasureDb::new(),
            measurer,
            rng: Rng::new(seed ^ 0xD1CE),
            n_trials,
            batch_size,
            prior: Vec::new(),
            budget: None,
            warm_seeds: Vec::new(),
        }
    }

    /// Attach a [`MeasureBudget`]: forwarded into the measurement
    /// substrate (so every sim/full pass is booked) and kept here so
    /// [`Tuner::tune_halving`] can advance the rung pointer.
    pub fn attach_budget(&mut self, budget: MeasureBudget) {
        self.measurer.attach_budget(budget.clone());
        self.budget = Some(budget);
    }

    /// Inject warm-start candidates measured (or screened) ahead of the
    /// explorer's own proposals in the first round. Already-measured
    /// seeds and duplicates are skipped; seeds beyond the first round's
    /// size are dropped (they are hints, not obligations).
    pub fn set_warm_seeds(&mut self, seeds: Vec<Genotype>) {
        self.warm_seeds = seeds;
    }

    /// Warm-start from another workload's measurement database: its
    /// (config, runtime) rows are featurized under `prior_wl` and kept in
    /// the training set, and the cost model is trained immediately, so the
    /// very first proposal batch is already model-guided instead of random.
    /// The prior may be any operator — cross-operator transfer works
    /// through the shared feature space.
    pub fn with_transfer(mut self, prior_wl: impl Into<OpWorkload>, prior_db: &MeasureDb) -> Self {
        let prior_wl = prior_wl.into();
        let rows = prior_db
            .iter()
            .map(|(_, cfg, rt)| (featurize(&prior_wl, cfg), *rt))
            .collect();
        self.set_prior(rows);
        self
    }

    /// Install pre-featurized transfer rows (the [`Session`] path):
    /// pretrains the model right away ([`CostModel::pretrain`], which
    /// skips priors too small to rank on) and keeps the rows in every
    /// subsequent retraining set.
    pub fn set_prior(&mut self, rows: Vec<(Vec<f64>, f64)>) {
        self.prior = rows;
        self.model.pretrain(&self.prior);
    }

    /// The search space this tuner explores.
    pub fn space(&self) -> &SearchSpace {
        &self.space
    }

    /// Every measurement paid for so far.
    pub fn db(&self) -> &MeasureDb {
        &self.db
    }

    /// Consume the tuner, keeping its measurement database (what a
    /// [`SessionResult`] carries forward for transfer learning).
    pub fn into_db(self) -> MeasureDb {
        self.db
    }

    /// Run one explore→measure→train round; returns how many configs were
    /// measured (0 = space exhausted).
    pub fn step(&mut self, history: &mut History) -> usize {
        let batch = self.propose_round(self.batch_size, &HashSet::new());
        if batch.is_empty() {
            return 0;
        }
        let measured = self.measure_batch(&batch, history);
        self.retrain();
        measured
    }

    /// One round's candidates: warm seeds first (drained once, deduped
    /// against everything measured or screened), then explorer proposals
    /// for the remainder. With no seeds and no screened set this is
    /// byte-for-byte the old proposal path — same borrows, same RNG
    /// stream — so flat sessions replay unchanged.
    fn propose_round(&mut self, want: usize, screened: &HashSet<Genotype>) -> Vec<Genotype> {
        if self.warm_seeds.is_empty() && screened.is_empty() {
            return self.explorer.propose(
                self.model.as_ref(),
                self.db.measured_set(),
                want,
                &mut self.rng,
            );
        }
        let mut exclude = self.db.measured_union(screened);
        let mut batch: Vec<Genotype> = Vec::new();
        for g in std::mem::take(&mut self.warm_seeds) {
            if batch.len() < want && exclude.insert(g.clone()) {
                batch.push(g);
            }
        }
        if batch.len() < want {
            let more = self.explorer.propose(
                self.model.as_ref(),
                &exclude,
                want - batch.len(),
                &mut self.rng,
            );
            batch.extend(more);
        }
        batch
    }

    /// Measure one proposal batch through the substrate's batch entry
    /// point ([`Measurer::measure_batch`]): a parallel substrate fans the
    /// whole round across its worker pool, while recording stays in
    /// candidate order, so the database and history are identical to a
    /// serial run's.
    fn measure_batch(&mut self, batch: &[Genotype], history: &mut History) -> usize {
        let cfgs: Vec<ScheduleConfig> = batch.iter().map(|g| self.space.decode(g)).collect();
        let measurements = self.measurer.measure_batch(&self.wl, &cfgs);
        debug_assert_eq!(measurements.len(), batch.len());
        for ((g, cfg), m) in batch.iter().zip(&cfgs).zip(measurements) {
            self.db.record(g.clone(), *cfg, m.runtime_us);
            history.push(*cfg, m.runtime_us, self.wl.ops());
        }
        batch.len()
    }

    fn retrain(&mut self) {
        let wl = &self.wl;
        let (mut xs, mut ys): (Vec<Vec<f64>>, Vec<f64>) = self
            .db
            .iter()
            .map(|(_, cfg, rt)| (featurize(wl, cfg), *rt))
            .unzip();
        for (x, y) in &self.prior {
            xs.push(x.clone());
            ys.push(*y);
        }
        self.model.train(&xs, &ys);
    }

    /// Run the full session: `n_trials` measurements (or until the space
    /// is exhausted), returning the best schedule.
    pub fn tune(&mut self) -> TuneResult {
        let mut history = History::new(self.explorer.name());
        while self.db.len() < self.n_trials {
            if self.step(&mut history) == 0 {
                break;
            }
        }
        let (cfg, rt) = self.db.best().expect("tuner measured nothing");
        TuneResult {
            config: cfg,
            runtime_us: rt,
            trials_used: self.db.len(),
            history,
            rungs: Vec::new(),
        }
    }

    /// Run the session with successive halving: each round screens a
    /// wide candidate field through `opts.low_rungs` cheap low-rep
    /// simulation rungs — rung `r` at [`Fidelity::Low`]`(eta^r)`,
    /// keeping the best `ceil(entrants / eta)` each time — and only the
    /// surviving distinctive candidates reach the full-fidelity rung
    /// that spends real `n_trials` budget and trains the model.
    ///
    /// Low-fidelity results *rank*, they are never *recorded*: the
    /// database, history, and cost-model training set hold full-fidelity
    /// numbers only, and screened-out candidates are excluded from
    /// re-proposal for the rest of the session. Everything is booked in
    /// the attached [`MeasureBudget`] per rung, and the per-rung
    /// survivor lists come back in [`TuneResult::rungs`] — equal seeds
    /// replay them bit-for-bit.
    pub fn tune_halving(&mut self, opts: HalvingOptions) -> TuneResult {
        let eta = opts.eta.max(2);
        let field = if opts.field == 0 { self.batch_size * 8 } else { opts.field };
        let mut history = History::new(self.explorer.name());
        let mut rungs: Vec<RungRecord> = Vec::new();
        let mut screened: HashSet<Genotype> = HashSet::new();
        let mut round = 0;
        while self.db.len() < self.n_trials {
            let mut entrants = self.propose_round(field, &screened);
            if entrants.is_empty() {
                break;
            }
            for r in 0..opts.low_rungs {
                if entrants.len() <= 1 {
                    break;
                }
                let fidelity = Fidelity::Low(eta.pow(r as u32) as u32);
                let rung = rungs.len();
                if let Some(b) = &self.budget {
                    b.set_rung(rung);
                }
                let cfgs: Vec<ScheduleConfig> =
                    entrants.iter().map(|g| self.space.decode(g)).collect();
                let ms = self.measurer.measure_batch_at(&self.wl, &cfgs, fidelity);
                debug_assert_eq!(ms.len(), entrants.len());
                // rank: feasible before infeasible, faster before slower,
                // proposal order as the deterministic tiebreak
                let mut order: Vec<usize> = (0..entrants.len()).collect();
                order.sort_by(|&a, &b| {
                    let ka = ((!ms[a].feasible) as u8, ms[a].runtime_us);
                    let kb = ((!ms[b].feasible) as u8, ms[b].runtime_us);
                    ka.partial_cmp(&kb).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
                });
                let keep = entrants.len().div_ceil(eta).max(1);
                screened.extend(entrants.iter().cloned());
                let survivors: Vec<Genotype> =
                    order[..keep].iter().map(|&i| entrants[i].clone()).collect();
                rungs.push(RungRecord {
                    round,
                    rung,
                    fidelity,
                    entrants: entrants.len(),
                    survivors: survivors.clone(),
                });
                entrants = survivors;
            }
            // final rung: full fidelity on the survivors, truncated to the
            // remaining real-measurement budget
            entrants.truncate(self.n_trials - self.db.len());
            if entrants.is_empty() {
                break;
            }
            let rung = rungs.len();
            if let Some(b) = &self.budget {
                b.set_rung(rung);
            }
            let measured = self.measure_batch(&entrants, &mut history);
            rungs.push(RungRecord {
                round,
                rung,
                fidelity: Fidelity::Full,
                entrants: measured,
                survivors: entrants,
            });
            self.retrain();
            round += 1;
        }
        let (config, runtime_us) = self.db.best().expect("tuner measured nothing");
        TuneResult { config, runtime_us, trials_used: self.db.len(), history, rungs }
    }
}

/// Exhaustively measure the whole space (Table 1's "Exhaustive" row).
/// Returns (best config, best runtime, configs measured).
pub fn exhaustive_best(
    wl: impl Into<OpWorkload>,
    space_opts: SpaceOptions,
    sim: &Simulator,
) -> (ScheduleConfig, f64, usize) {
    let wl = wl.into();
    let space = SearchSpace::for_workload(&wl, space_opts);
    let mut measurer = SimMeasurer::new(sim.clone());
    let mut best: Option<(ScheduleConfig, f64)> = None;
    let legal = space.enumerate_legal();
    let n = legal.len();
    for g in legal {
        let cfg = space.decode(&g);
        let rt = measurer.measure(&wl, &cfg).runtime_us;
        if best.as_ref().map_or(true, |(_, b)| rt < *b) {
            best = Some((cfg, rt));
        }
    }
    let (cfg, rt) = best.expect("no legal configs");
    (cfg, rt, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvWorkload;
    use crate::sim::GpuSpec;

    #[test]
    fn transfer_warm_start_speeds_early_search() {
        // tune stage3 cold vs warm-started from stage2's measurements;
        // the warm tuner's early best should be at least as good on
        // average (shared tile structure transfers through the
        // workload-context features)
        let src = ConvWorkload::resnet50_stage(2, 8);
        let dst = ConvWorkload::resnet50_stage(3, 8);
        let mut cold_sum = 0.0;
        let mut warm_sum = 0.0;
        for seed in [3u64, 5, 9] {
            let opts = |s: u64| TunerOptions {
                n_trials: 96,
                seed: s,
                measurer: Simulator { noise_sigma: 0.02, seed: s, ..Default::default() }
                    .into_measurer(),
                ..Default::default()
            };
            // source session provides the prior
            let mut src_tuner = Tuner::new(&src, opts(seed));
            src_tuner.tune();
            let mut warm = Tuner::new(&dst, opts(seed)).with_transfer(&src, src_tuner.db());
            let mut cold = Tuner::new(&dst, opts(seed));
            warm_sum += warm.tune().history.best_after(32);
            cold_sum += cold.tune().history.best_after(32);
        }
        assert!(
            warm_sum <= cold_sum * 1.05,
            "warm {warm_sum} vs cold {cold_sum} (best@32, 3 seeds)"
        );
    }

    fn quick_opts(explorer: ExplorerKind, n_trials: usize, seed: u64) -> TunerOptions {
        TunerOptions {
            n_trials,
            explorer,
            seed,
            measurer: Simulator { noise_sigma: 0.01, seed, ..Default::default() }
                .into_measurer(),
            ..Default::default()
        }
    }

    #[test]
    fn tuner_improves_over_first_batch() {
        let wl = ConvWorkload::resnet50_stage(2, 8);
        let mut t = Tuner::new(&wl, quick_opts(ExplorerKind::SimulatedAnnealing, 160, 1));
        let res = t.tune();
        let first_batch_best = res.history.best_after(32);
        assert!(
            res.runtime_us <= first_batch_best,
            "final {} vs first-batch {first_batch_best}",
            res.runtime_us
        );
        assert_eq!(res.trials_used, 160);
    }

    #[test]
    fn tuner_never_measures_twice() {
        let wl = ConvWorkload::resnet50_stage(4, 8);
        let mut t = Tuner::new(&wl, quick_opts(ExplorerKind::DiversityAware, 96, 3));
        let res = t.tune();
        assert_eq!(res.trials_used, t.db.len());
        // MeasureDb keys are genotypes; len == distinct count by
        // construction. Verify against history length too.
        assert_eq!(res.history.len(), t.db.len());
    }

    #[test]
    fn tuned_close_to_exhaustive_optimum() {
        let wl = ConvWorkload::resnet50_stage(3, 8);
        let sim = Simulator::noiseless(GpuSpec::t4());
        let (_, best_rt, n_legal) = exhaustive_best(&wl, SpaceOptions::default(), &sim);
        let mut t = Tuner::new(
            &wl,
            TunerOptions {
                n_trials: 400,
                explorer: ExplorerKind::DiversityAware,
                measurer: Simulator::noiseless(GpuSpec::t4()).into_measurer(),
                seed: 7,
                ..Default::default()
            },
        );
        let res = t.tune();
        // §4.2: "automatic-searched performance is faster or similar" —
        // within 10% of the exhaustive optimum on far fewer trials
        assert!(res.trials_used < n_legal);
        assert!(
            res.runtime_us <= best_rt * 1.10,
            "tuned {} vs exhaustive {best_rt}",
            res.runtime_us
        );
    }

    #[test]
    fn history_best_curve_is_monotone() {
        let wl = ConvWorkload::resnet50_stage(5, 8);
        let mut t = Tuner::new(&wl, quick_opts(ExplorerKind::Random, 64, 9));
        let res = t.tune();
        let curve = res.history.best_curve();
        for w in curve.windows(2) {
            assert!(w[1] <= w[0] * 1.0000001);
        }
    }

    #[test]
    fn exhaustive_explorer_coverage_matches_space() {
        let wl = ConvWorkload::resnet50_stage(5, 8);
        let space = SearchSpace::for_workload(&wl, SpaceOptions::autotvm_original());
        let n_legal = space.enumerate_legal().len();
        let mut t = Tuner::new(
            &wl,
            TunerOptions {
                n_trials: usize::MAX,
                explorer: ExplorerKind::Exhaustive,
                space: SpaceOptions::autotvm_original(),
                ..Default::default()
            },
        );
        let res = t.tune();
        assert_eq!(res.trials_used, n_legal);
    }

    #[test]
    fn parallel_tuner_run_is_bit_identical_to_serial() {
        // the tentpole invariant: the same seed tunes to the same best
        // schedule (and the same full history) whether candidates are
        // measured on one thread or fanned across four — the simulator's
        // noise is keyed per candidate, and the pool merges results in
        // candidate order
        let wl = ConvWorkload::resnet50_stage(2, 8);
        let run = |jobs: usize| {
            let sim = Simulator { noise_sigma: 0.02, seed: 6, ..Default::default() };
            let measurer: Box<dyn Measurer> = if jobs > 1 {
                ParallelMeasurer::boxed(sim, jobs)
            } else {
                sim.into_measurer()
            };
            let mut t = Tuner::new(
                &wl,
                TunerOptions { n_trials: 96, seed: 6, measurer, ..Default::default() },
            );
            t.tune()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.config, parallel.config);
        assert_eq!(serial.runtime_us, parallel.runtime_us);
        assert_eq!(serial.trials_used, parallel.trials_used);
        let a: Vec<f64> = serial.history.records().iter().map(|r| r.runtime_us).collect();
        let b: Vec<f64> = parallel.history.records().iter().map(|r| r.runtime_us).collect();
        assert_eq!(a, b, "full measurement sequence must match trial-for-trial");
    }

    #[test]
    fn halving_books_every_rung_and_replays_bit_for_bit() {
        let wl = ConvWorkload::resnet50_stage(3, 8);
        let run = |seed: u64| {
            let budget = MeasureBudget::new();
            let mut t = Tuner::new(&wl, quick_opts(ExplorerKind::DiversityAware, 48, seed));
            t.attach_budget(budget.clone());
            (t.tune_halving(HalvingOptions::default()), budget)
        };
        let (res, budget) = run(11);

        // the ledger's full-fidelity count IS the trial count — halving's
        // claim is auditable by counter, not by clock
        assert_eq!(budget.full_total(), res.trials_used);
        assert!(res.trials_used <= 48);
        assert!(budget.low_total() > 0, "screening rungs ran");
        // screening touched a wider field than the full budget paid for
        let screened: usize = res
            .rungs
            .iter()
            .filter(|r| matches!(r.fidelity, Fidelity::Low(_)))
            .map(|r| r.entrants)
            .sum();
        assert!(screened > res.trials_used);

        // each RungRecord row reconciles against the ledger row it names
        let rows = budget.rungs();
        assert_eq!(rows.len(), res.rungs.len());
        for rec in &res.rungs {
            let row = rows[rec.rung];
            match rec.fidelity {
                Fidelity::Low(reps) => {
                    assert_eq!(row.low, rec.entrants * reps.max(1) as usize);
                    assert_eq!(row.full, 0);
                }
                Fidelity::Full => {
                    assert_eq!(row.full, rec.entrants);
                    assert_eq!(row.low, 0);
                }
            }
            assert!(rec.survivors.len() <= rec.entrants);
        }

        // equal seeds replay identical rung survivors, bit for bit
        let (res2, _) = run(11);
        assert_eq!(res.rungs, res2.rungs);
        assert_eq!(res.config, res2.config);
        assert_eq!(res.runtime_us, res2.runtime_us);
        // a different seed screens a different field
        let (res3, _) = run(12);
        assert_ne!(res.rungs, res3.rungs);
    }

    #[test]
    fn warm_seeds_lead_the_first_round_once() {
        let wl = ConvWorkload::resnet50_stage(4, 8);
        let space = SearchSpace::for_workload(&wl, SpaceOptions::default());
        let mut rng = Rng::new(17);
        let seed_g = space.random_legal(&mut rng);
        let mut t = Tuner::new(&wl, quick_opts(ExplorerKind::DiversityAware, 32, 17));
        t.set_warm_seeds(vec![seed_g.clone(), seed_g.clone()]);
        let res = t.tune();
        assert!(t.db().contains(&seed_g), "warm seed was measured");
        // duplicate seed injected once; first trial is the seed's config
        assert_eq!(res.history.records()[0].config, space.decode(&seed_g));
        assert_eq!(res.trials_used, t.db().len());
    }

    #[test]
    fn cached_measurer_composes_with_tuner() {
        // the decorator is transparent: same seed, same proposals, same
        // best — and the no-remeasure discipline means zero cache hits
        // within a single session
        let wl = ConvWorkload::resnet50_stage(3, 8);
        let run = |cached: bool| {
            let base = Simulator { noise_sigma: 0.01, seed: 2, ..Default::default() };
            let measurer: Box<dyn Measurer> = if cached {
                Box::new(CachedMeasurer::new(base.into_measurer()))
            } else {
                base.into_measurer()
            };
            let mut t = Tuner::new(
                &wl,
                TunerOptions { n_trials: 64, seed: 2, measurer, ..Default::default() },
            );
            t.tune().runtime_us
        };
        assert_eq!(run(false), run(true));
    }
}
