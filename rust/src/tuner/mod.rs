//! Tuner orchestration: the measure→train→explore loop of AutoTVM with the
//! paper's batch discipline (§4.1): batches of 32 configs measured per
//! round (top-31 model picks + 1 random), the cost model retrained on all
//! measurements after each round, and a database guaranteeing no config is
//! ever measured twice.
//!
//! Every pluggable stage sits behind a trait: exploration
//! ([`crate::explore::Explorer`], resolved by name through
//! [`crate::explore::ExplorerRegistry`]), cost modelling
//! ([`crate::costmodel::CostModel`]) and measurement
//! ([`crate::sim::Measurer`]). [`Session`] is the fluent front door that
//! wires them together and hands the result to the
//! [`crate::registry::ScheduleRegistry`] serving loads.
//!
//! Measurement — where all the wall-clock time goes — is issued per
//! *round*, not per candidate: [`Tuner::step`] hands the whole proposal
//! batch to [`Measurer::measure_batch`], so a parallel substrate
//! ([`crate::sim::ParallelMeasurer`], selected by
//! [`SessionBuilder::parallelism`] or `repro tune --jobs n`) fans the round
//! across a worker pool while the results stay in candidate order —
//! parallel and serial sessions are bit-for-bit identical.
//!
//! Tuning also runs *online*: [`online::OnlineTuner`] watches a live
//! [`crate::serve::Server`]'s metrics for hot or schedule-less request
//! kinds, retunes them with bounded warm-started sessions, and publishes
//! the winners through the server's registry hot-reload path.

mod db;
mod history;
pub mod online;
mod session;

pub use db::MeasureDb;
pub use history::{History, TrialRecord};
pub use session::{Session, SessionBuilder, SessionResult};

// Re-export the measurement seam here too: tuning code is its main client.
pub use crate::sim::{CachedMeasurer, Measurer, ParallelMeasurer, SimMeasurer};

use crate::costmodel::{featurize, CostModel, Gbt, GbtParams};
use crate::explore::{Explorer, ExplorerKind};
use crate::searchspace::{Genotype, ScheduleConfig, SearchSpace, SpaceOptions};
use crate::sim::Simulator;
use crate::util::Rng;
use crate::workload::{OpWorkload, Workload};

/// Tuning-session options (§4.1 defaults).
pub struct TunerOptions {
    /// Total real-measurement budget ("500 trials" in the paper).
    pub n_trials: usize,
    /// Configs measured per round (31 model picks + 1 random).
    pub batch_size: usize,
    /// Builtin exploration module ([`Tuner::with_explorer`] call sites
    /// ignore this and supply a prebuilt one).
    pub explorer: ExplorerKind,
    /// Search-space shape (knob ranges / legality rules).
    pub space: SpaceOptions,
    /// Seed for everything stochastic in the session.
    pub seed: u64,
    /// Measurement substrate (replaces the old concrete `simulator` field;
    /// default: the noisy T4 simulator behind a [`SimMeasurer`]).
    pub measurer: Box<dyn Measurer>,
    /// Cost-model prototype; `None` = the GBT ranker seeded from `seed`.
    pub model: Option<Box<dyn CostModel>>,
}

impl Default for TunerOptions {
    fn default() -> Self {
        Self {
            n_trials: 500,
            batch_size: 32,
            explorer: ExplorerKind::DiversityAware,
            space: SpaceOptions::default(),
            seed: 0,
            measurer: Box::new(SimMeasurer::default()),
            model: None,
        }
    }
}

/// Best schedule found by a tuning session.
#[derive(Debug, Clone)]
pub struct TuneResult {
    /// The best schedule found.
    pub config: ScheduleConfig,
    /// Its measured (simulated) runtime, microseconds.
    pub runtime_us: f64,
    /// Measurements actually spent (≤ `n_trials`; less if the legal
    /// space was exhausted).
    pub trials_used: usize,
    /// Full per-trial log (Fig. 14's tuning curve).
    pub history: History,
}

/// One tuning session over one workload (any operator). Every
/// collaborator is a trait object — no concrete model or measurement
/// substrate appears in the fields.
pub struct Tuner {
    wl: OpWorkload,
    space: SearchSpace,
    explorer: Box<dyn Explorer>,
    model: Box<dyn CostModel>,
    db: MeasureDb,
    measurer: Box<dyn Measurer>,
    rng: Rng,
    n_trials: usize,
    batch_size: usize,
    /// Transfer-learning prior: (features, runtime) rows from other
    /// workloads, mixed into every retraining set. The feature vector
    /// includes workload-context dims, so one model ranks across convs
    /// (AutoTVM "accelerate[s] the process using transfer learning").
    prior: Vec<(Vec<f64>, f64)>,
}

impl Tuner {
    /// Assemble a tuner for one workload from options (builds the search
    /// space and the `opts.explorer` module; [`Session`] is the
    /// higher-level front door).
    pub fn new(wl: impl Into<OpWorkload>, opts: TunerOptions) -> Self {
        let wl = wl.into();
        let space = SearchSpace::for_workload(&wl, opts.space);
        let explorer = opts.explorer.build(&space);
        Self::assemble(wl, space, explorer, opts)
    }

    /// Construct with a caller-built explorer (how [`Session`] plugs in
    /// registry-resolved or custom exploration modules); `opts.explorer`
    /// is ignored.
    pub fn with_explorer(
        wl: impl Into<OpWorkload>,
        opts: TunerOptions,
        explorer: Box<dyn Explorer>,
    ) -> Self {
        let wl = wl.into();
        let space = SearchSpace::for_workload(&wl, opts.space);
        Self::assemble(wl, space, explorer, opts)
    }

    fn assemble(
        wl: OpWorkload,
        space: SearchSpace,
        explorer: Box<dyn Explorer>,
        opts: TunerOptions,
    ) -> Self {
        let TunerOptions { n_trials, batch_size, seed, measurer, model, .. } = opts;
        let model = model
            .unwrap_or_else(|| Box::new(Gbt::new(GbtParams { seed, ..Default::default() })));
        Self {
            wl,
            space,
            explorer,
            model,
            db: MeasureDb::new(),
            measurer,
            rng: Rng::new(seed ^ 0xD1CE),
            n_trials,
            batch_size,
            prior: Vec::new(),
        }
    }

    /// Warm-start from another workload's measurement database: its
    /// (config, runtime) rows are featurized under `prior_wl` and kept in
    /// the training set, and the cost model is trained immediately, so the
    /// very first proposal batch is already model-guided instead of random.
    /// The prior may be any operator — cross-operator transfer works
    /// through the shared feature space.
    pub fn with_transfer(mut self, prior_wl: impl Into<OpWorkload>, prior_db: &MeasureDb) -> Self {
        let prior_wl = prior_wl.into();
        let rows = prior_db
            .iter()
            .map(|(_, cfg, rt)| (featurize(&prior_wl, cfg), *rt))
            .collect();
        self.set_prior(rows);
        self
    }

    /// Install pre-featurized transfer rows (the [`Session`] path); trains
    /// the model right away once there is enough data.
    pub fn set_prior(&mut self, rows: Vec<(Vec<f64>, f64)>) {
        self.prior = rows;
        if self.prior.len() >= 4 {
            let (xs, ys): (Vec<Vec<f64>>, Vec<f64>) = self.prior.iter().cloned().unzip();
            self.model.train(&xs, &ys);
        }
    }

    /// The search space this tuner explores.
    pub fn space(&self) -> &SearchSpace {
        &self.space
    }

    /// Every measurement paid for so far.
    pub fn db(&self) -> &MeasureDb {
        &self.db
    }

    /// Consume the tuner, keeping its measurement database (what a
    /// [`SessionResult`] carries forward for transfer learning).
    pub fn into_db(self) -> MeasureDb {
        self.db
    }

    /// Run one explore→measure→train round; returns how many configs were
    /// measured (0 = space exhausted).
    pub fn step(&mut self, history: &mut History) -> usize {
        let batch = self.explorer.propose(
            self.model.as_ref(),
            self.db.measured_set(),
            self.batch_size,
            &mut self.rng,
        );
        if batch.is_empty() {
            return 0;
        }
        let measured = self.measure_batch(&batch, history);
        self.retrain();
        measured
    }

    /// Measure one proposal batch through the substrate's batch entry
    /// point ([`Measurer::measure_batch`]): a parallel substrate fans the
    /// whole round across its worker pool, while recording stays in
    /// candidate order, so the database and history are identical to a
    /// serial run's.
    fn measure_batch(&mut self, batch: &[Genotype], history: &mut History) -> usize {
        let cfgs: Vec<ScheduleConfig> = batch.iter().map(|g| self.space.decode(g)).collect();
        let measurements = self.measurer.measure_batch(&self.wl, &cfgs);
        debug_assert_eq!(measurements.len(), batch.len());
        for ((g, cfg), m) in batch.iter().zip(&cfgs).zip(measurements) {
            self.db.record(g.clone(), *cfg, m.runtime_us);
            history.push(*cfg, m.runtime_us, self.wl.ops());
        }
        batch.len()
    }

    fn retrain(&mut self) {
        let wl = &self.wl;
        let (mut xs, mut ys): (Vec<Vec<f64>>, Vec<f64>) = self
            .db
            .iter()
            .map(|(_, cfg, rt)| (featurize(wl, cfg), *rt))
            .unzip();
        for (x, y) in &self.prior {
            xs.push(x.clone());
            ys.push(*y);
        }
        self.model.train(&xs, &ys);
    }

    /// Run the full session: `n_trials` measurements (or until the space
    /// is exhausted), returning the best schedule.
    pub fn tune(&mut self) -> TuneResult {
        let mut history = History::new(self.explorer.name());
        while self.db.len() < self.n_trials {
            if self.step(&mut history) == 0 {
                break;
            }
        }
        let (cfg, rt) = self.db.best().expect("tuner measured nothing");
        TuneResult {
            config: cfg,
            runtime_us: rt,
            trials_used: self.db.len(),
            history,
        }
    }
}

/// Exhaustively measure the whole space (Table 1's "Exhaustive" row).
/// Returns (best config, best runtime, configs measured).
pub fn exhaustive_best(
    wl: impl Into<OpWorkload>,
    space_opts: SpaceOptions,
    sim: &Simulator,
) -> (ScheduleConfig, f64, usize) {
    let wl = wl.into();
    let space = SearchSpace::for_workload(&wl, space_opts);
    let mut measurer = SimMeasurer::new(sim.clone());
    let mut best: Option<(ScheduleConfig, f64)> = None;
    let legal = space.enumerate_legal();
    let n = legal.len();
    for g in legal {
        let cfg = space.decode(&g);
        let rt = measurer.measure(&wl, &cfg).runtime_us;
        if best.as_ref().map_or(true, |(_, b)| rt < *b) {
            best = Some((cfg, rt));
        }
    }
    let (cfg, rt) = best.expect("no legal configs");
    (cfg, rt, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvWorkload;
    use crate::sim::GpuSpec;

    #[test]
    fn transfer_warm_start_speeds_early_search() {
        // tune stage3 cold vs warm-started from stage2's measurements;
        // the warm tuner's early best should be at least as good on
        // average (shared tile structure transfers through the
        // workload-context features)
        let src = ConvWorkload::resnet50_stage(2, 8);
        let dst = ConvWorkload::resnet50_stage(3, 8);
        let mut cold_sum = 0.0;
        let mut warm_sum = 0.0;
        for seed in [3u64, 5, 9] {
            let opts = |s: u64| TunerOptions {
                n_trials: 96,
                seed: s,
                measurer: Simulator { noise_sigma: 0.02, seed: s, ..Default::default() }
                    .into_measurer(),
                ..Default::default()
            };
            // source session provides the prior
            let mut src_tuner = Tuner::new(&src, opts(seed));
            src_tuner.tune();
            let mut warm = Tuner::new(&dst, opts(seed)).with_transfer(&src, src_tuner.db());
            let mut cold = Tuner::new(&dst, opts(seed));
            warm_sum += warm.tune().history.best_after(32);
            cold_sum += cold.tune().history.best_after(32);
        }
        assert!(
            warm_sum <= cold_sum * 1.05,
            "warm {warm_sum} vs cold {cold_sum} (best@32, 3 seeds)"
        );
    }

    fn quick_opts(explorer: ExplorerKind, n_trials: usize, seed: u64) -> TunerOptions {
        TunerOptions {
            n_trials,
            explorer,
            seed,
            measurer: Simulator { noise_sigma: 0.01, seed, ..Default::default() }
                .into_measurer(),
            ..Default::default()
        }
    }

    #[test]
    fn tuner_improves_over_first_batch() {
        let wl = ConvWorkload::resnet50_stage(2, 8);
        let mut t = Tuner::new(&wl, quick_opts(ExplorerKind::SimulatedAnnealing, 160, 1));
        let res = t.tune();
        let first_batch_best = res.history.best_after(32);
        assert!(
            res.runtime_us <= first_batch_best,
            "final {} vs first-batch {first_batch_best}",
            res.runtime_us
        );
        assert_eq!(res.trials_used, 160);
    }

    #[test]
    fn tuner_never_measures_twice() {
        let wl = ConvWorkload::resnet50_stage(4, 8);
        let mut t = Tuner::new(&wl, quick_opts(ExplorerKind::DiversityAware, 96, 3));
        let res = t.tune();
        assert_eq!(res.trials_used, t.db.len());
        // MeasureDb keys are genotypes; len == distinct count by
        // construction. Verify against history length too.
        assert_eq!(res.history.len(), t.db.len());
    }

    #[test]
    fn tuned_close_to_exhaustive_optimum() {
        let wl = ConvWorkload::resnet50_stage(3, 8);
        let sim = Simulator::noiseless(GpuSpec::t4());
        let (_, best_rt, n_legal) = exhaustive_best(&wl, SpaceOptions::default(), &sim);
        let mut t = Tuner::new(
            &wl,
            TunerOptions {
                n_trials: 400,
                explorer: ExplorerKind::DiversityAware,
                measurer: Simulator::noiseless(GpuSpec::t4()).into_measurer(),
                seed: 7,
                ..Default::default()
            },
        );
        let res = t.tune();
        // §4.2: "automatic-searched performance is faster or similar" —
        // within 10% of the exhaustive optimum on far fewer trials
        assert!(res.trials_used < n_legal);
        assert!(
            res.runtime_us <= best_rt * 1.10,
            "tuned {} vs exhaustive {best_rt}",
            res.runtime_us
        );
    }

    #[test]
    fn history_best_curve_is_monotone() {
        let wl = ConvWorkload::resnet50_stage(5, 8);
        let mut t = Tuner::new(&wl, quick_opts(ExplorerKind::Random, 64, 9));
        let res = t.tune();
        let curve = res.history.best_curve();
        for w in curve.windows(2) {
            assert!(w[1] <= w[0] * 1.0000001);
        }
    }

    #[test]
    fn exhaustive_explorer_coverage_matches_space() {
        let wl = ConvWorkload::resnet50_stage(5, 8);
        let space = SearchSpace::for_workload(&wl, SpaceOptions::autotvm_original());
        let n_legal = space.enumerate_legal().len();
        let mut t = Tuner::new(
            &wl,
            TunerOptions {
                n_trials: usize::MAX,
                explorer: ExplorerKind::Exhaustive,
                space: SpaceOptions::autotvm_original(),
                ..Default::default()
            },
        );
        let res = t.tune();
        assert_eq!(res.trials_used, n_legal);
    }

    #[test]
    fn parallel_tuner_run_is_bit_identical_to_serial() {
        // the tentpole invariant: the same seed tunes to the same best
        // schedule (and the same full history) whether candidates are
        // measured on one thread or fanned across four — the simulator's
        // noise is keyed per candidate, and the pool merges results in
        // candidate order
        let wl = ConvWorkload::resnet50_stage(2, 8);
        let run = |jobs: usize| {
            let sim = Simulator { noise_sigma: 0.02, seed: 6, ..Default::default() };
            let measurer: Box<dyn Measurer> = if jobs > 1 {
                ParallelMeasurer::boxed(sim, jobs)
            } else {
                sim.into_measurer()
            };
            let mut t = Tuner::new(
                &wl,
                TunerOptions { n_trials: 96, seed: 6, measurer, ..Default::default() },
            );
            t.tune()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.config, parallel.config);
        assert_eq!(serial.runtime_us, parallel.runtime_us);
        assert_eq!(serial.trials_used, parallel.trials_used);
        let a: Vec<f64> = serial.history.records().iter().map(|r| r.runtime_us).collect();
        let b: Vec<f64> = parallel.history.records().iter().map(|r| r.runtime_us).collect();
        assert_eq!(a, b, "full measurement sequence must match trial-for-trial");
    }

    #[test]
    fn cached_measurer_composes_with_tuner() {
        // the decorator is transparent: same seed, same proposals, same
        // best — and the no-remeasure discipline means zero cache hits
        // within a single session
        let wl = ConvWorkload::resnet50_stage(3, 8);
        let run = |cached: bool| {
            let base = Simulator { noise_sigma: 0.01, seed: 2, ..Default::default() };
            let measurer: Box<dyn Measurer> = if cached {
                Box::new(CachedMeasurer::new(base.into_measurer()))
            } else {
                base.into_measurer()
            };
            let mut t = Tuner::new(
                &wl,
                TunerOptions { n_trials: 64, seed: 2, measurer, ..Default::default() },
            );
            t.tune().runtime_us
        };
        assert_eq!(run(false), run(true));
    }
}
