//! The cross-session tune cache: a durable, fingerprint-keyed store of
//! tuned schedules with provenance.
//!
//! Tuning cost is the barrier to "every shape a user sends gets tuned" —
//! a [`crate::tuner::Session`] spends hundreds of measurements per
//! shape, and a fleet re-pays that bill for every process, every
//! restart, every near-duplicate shape. The [`TuneCache`] amortizes it
//! across sessions the way the schedule registry amortizes it across
//! requests:
//!
//! * the key is a **[`Fingerprint`]** — operator family + the GEMM
//!   legality shape with each dimension *anchored* (bucketed up to the
//!   next power of two, the same shape-bucketing trick durable autotune
//!   caches use) + precision + groups. Near-identical shapes share a
//!   bucket; distinct precisions or group counts never collide.
//! * an **exact fingerprint hit** (with the cached schedule still legal
//!   for the concrete shape) serves the schedule with **zero
//!   measurements**;
//! * a **nearest-anchor miss** (same operator/precision/groups,
//!   different bucket) warm-starts the explorer from the cached
//!   schedule's one-knob neighborhood instead of uniform random;
//! * every entry carries **provenance** — trials spent, measurement
//!   fidelity, source session seed, and the registry schema version in
//!   force when it was written — so a served schedule is auditable back
//!   to the session that earned it.
//!
//! The JSON artifact is versioned like the schedule registry, and a
//! corrupted or truncated file is **rejected and rebuilt** (the cache is
//! an accelerator, never a correctness dependency — garbage in the file
//! must never become garbage in the serving path).

use std::collections::hash_map::DefaultHasher;
use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::costmodel::featurize;
use crate::registry::REGISTRY_VERSION;
use crate::searchspace::ScheduleConfig;
use crate::util::Json;
use crate::workload::{OpWorkload, Precision, Workload};

/// Schema version written by [`TuneCache::to_json`].
pub const TUNE_CACHE_VERSION: usize = 1;

/// Anchor one GEMM dimension: bucket up to the next power of two (and
/// at least 1), so shapes that differ only by ragged edges share a key.
fn anchor_dim(d: usize) -> usize {
    d.max(1).next_power_of_two()
}

/// The problem identity a tuned schedule transfers across: operator
/// family, anchored GEMM shape, precision, and group count.
///
/// Anchoring reuses the [`Workload::profile_key`] idea (hash the
/// operator tag plus the shape) but buckets each legality-GEMM dimension
/// up to its power-of-two anchor first — `M = 25088` and `M = 25000`
/// land on the same key, while `Int4` vs `Int8` or `groups = 1` vs `32`
/// never can (they are distinct key components, not hashed away).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Fingerprint {
    /// Operator family tag (`"conv"`, `"matmul"`).
    pub op: String,
    /// Anchored (M, N, K) of the workload's legality GEMM.
    pub anchor: (usize, usize, usize),
    /// Reduced-precision data type.
    pub precision: Precision,
    /// Group count (per-group GEMMs tune differently from dense ones).
    pub groups: usize,
}

impl Fingerprint {
    /// The fingerprint of one workload.
    pub fn of(wl: &OpWorkload) -> Self {
        let (m, n, k) = wl.legality_gemm();
        Self {
            op: wl.op_name().to_string(),
            anchor: (anchor_dim(m), anchor_dim(n), anchor_dim(k)),
            precision: wl.precision(),
            groups: wl.groups(),
        }
    }

    /// The JSON map key: human-readable, sorted stably, collision-free
    /// across precisions and groups by construction.
    pub fn key(&self) -> String {
        let (m, n, k) = self.anchor;
        format!("{}:m{}:n{}:k{}:{}:g{}", self.op, m, n, k, self.precision.tag(), self.groups)
    }

    /// The fingerprint as a hash — the [`Workload::profile_key`]-style
    /// u64 form, for callers that want a compact cache key.
    pub fn hash_key(&self) -> u64 {
        let mut h = DefaultHasher::new();
        self.op.hash(&mut h);
        self.anchor.hash(&mut h);
        self.precision.tag().hash(&mut h);
        self.groups.hash(&mut h);
        h.finish()
    }

    /// Log-space distance between two fingerprints' anchors, or `None`
    /// when they differ in operator, precision, or groups (schedules
    /// never transfer across those — a warm start from the wrong
    /// precision would seed the search with an illegal tile geometry).
    pub fn anchor_distance(&self, other: &Fingerprint) -> Option<u32> {
        if self.op != other.op
            || self.precision != other.precision
            || self.groups != other.groups
        {
            return None;
        }
        let d = |a: usize, b: usize| {
            (a.trailing_zeros() as i64 - b.trailing_zeros() as i64).unsigned_abs() as u32
        };
        let (am, an, ak) = self.anchor;
        let (bm, bn, bk) = other.anchor;
        Some(d(am, bm) + d(an, bn) + d(ak, bk))
    }
}

/// One cached tuning result: the schedule plus full provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheEntry {
    /// The workload the session actually tuned (the bucket's concrete
    /// representative — also what GBT pretraining featurizes).
    pub workload: OpWorkload,
    /// The best schedule that session found.
    pub config: ScheduleConfig,
    /// Its tuned (simulated) runtime, microseconds.
    pub runtime_us: f64,
    /// Full-fidelity trials the source session spent earning it.
    pub trials: usize,
    /// Measurement fidelity provenance: `"multi"` (successive halving)
    /// or `"flat"` (every candidate measured fully).
    pub fidelity: String,
    /// Seed of the source session (replays the tune bit-for-bit).
    pub seed: u64,
    /// [`crate::registry::REGISTRY_VERSION`] in force when written.
    pub registry_version: usize,
}

impl CacheEntry {
    /// The fingerprint this entry files under.
    pub fn fingerprint(&self) -> Fingerprint {
        Fingerprint::of(&self.workload)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workload", self.workload.as_workload().to_json()),
            ("schedule", self.config.to_json()),
            ("runtime_us", Json::Num(self.runtime_us)),
            ("trials", Json::Num(self.trials as f64)),
            ("fidelity", Json::Str(self.fidelity.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("registry_version", Json::Num(self.registry_version as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            workload: OpWorkload::from_json(j.req("workload")?)?,
            config: ScheduleConfig::from_json(j.req("schedule")?)?,
            runtime_us: j
                .req("runtime_us")?
                .as_f64()
                .ok_or_else(|| anyhow!("runtime_us not a number"))?,
            trials: j.get("trials").and_then(Json::as_usize).unwrap_or(0),
            fidelity: j
                .get("fidelity")
                .and_then(Json::as_str)
                .unwrap_or("flat")
                .to_string(),
            seed: j.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64,
            registry_version: j
                .get("registry_version")
                .and_then(Json::as_usize)
                .unwrap_or(REGISTRY_VERSION),
        })
    }
}

/// `{fingerprint → tuned schedule + provenance}` — the durable
/// cross-session store (see the module docs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TuneCache {
    entries: BTreeMap<String, CacheEntry>,
}

impl TuneCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// How many fingerprint buckets hold an entry.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the cache holds nothing.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Every `(fingerprint key, entry)` pair, sorted by key.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &CacheEntry)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// File `entry` under its fingerprint. A bucket keeps its
    /// best-known result: an existing entry is replaced only by a
    /// strictly faster one (or an equal-runtime one earned with more
    /// trials). Returns whether the entry was stored.
    pub fn insert(&mut self, entry: CacheEntry) -> bool {
        let key = entry.fingerprint().key();
        match self.entries.get(&key) {
            Some(old)
                if old.runtime_us < entry.runtime_us
                    || (old.runtime_us == entry.runtime_us && old.trials >= entry.trials) =>
            {
                false
            }
            _ => {
                self.entries.insert(key, entry);
                true
            }
        }
    }

    /// Exact-fingerprint lookup.
    pub fn lookup(&self, fp: &Fingerprint) -> Option<&CacheEntry> {
        self.entries.get(&fp.key())
    }

    /// The closest entry by anchor distance among those sharing `fp`'s
    /// operator, precision, and groups — the warm-start donor for a
    /// miss. Ties break on the smaller key, so the choice is
    /// deterministic across sessions.
    pub fn nearest(&self, fp: &Fingerprint) -> Option<(&CacheEntry, u32)> {
        self.entries
            .values()
            .filter_map(|e| fp.anchor_distance(&e.fingerprint()).map(|d| (e, d)))
            .min_by_key(|(e, d)| (*d, e.fingerprint().key()))
    }

    /// Featurized `(features, runtime_us)` rows from every entry — the
    /// GBT pretraining prior a cold session can fit before its first
    /// measurement (the feature space carries workload context dims, so
    /// rows transfer across shapes and operators).
    pub fn pretrain_rows(&self) -> Vec<(Vec<f64>, f64)> {
        self.entries
            .values()
            .map(|e| (featurize(e.workload.as_workload(), &e.config), e.runtime_us))
            .collect()
    }

    // ----- JSON interchange ------------------------------------------------

    /// Serialize to the versioned JSON schema.
    pub fn to_json(&self) -> Json {
        let entries: BTreeMap<String, Json> =
            self.entries.iter().map(|(k, v)| (k.clone(), v.to_json())).collect();
        Json::obj(vec![
            ("version", Json::Num(TUNE_CACHE_VERSION as f64)),
            ("entries", Json::Obj(entries)),
        ])
    }

    /// Parse the versioned schema; rejects unknown versions, malformed
    /// entries, and entries whose stored workload does not reproduce
    /// the key they are filed under (a swapped or hand-edited entry
    /// must not serve under the wrong fingerprint).
    pub fn from_json(j: &Json) -> Result<Self> {
        let version = j
            .req("version")?
            .as_usize()
            .ok_or_else(|| anyhow!("tune-cache version not an integer"))?;
        if version != TUNE_CACHE_VERSION {
            bail!("unsupported tune-cache version {version} (want {TUNE_CACHE_VERSION})");
        }
        let entries = j
            .req("entries")?
            .as_obj()
            .ok_or_else(|| anyhow!("'entries' not an object"))?;
        let mut out = Self::new();
        for (key, entry) in entries {
            let entry = CacheEntry::from_json(entry)
                .with_context(|| format!("tune-cache entry '{key}'"))?;
            let expect = entry.fingerprint().key();
            if *key != expect {
                bail!("tune-cache entry '{key}' does not match its workload ('{expect}')");
            }
            out.entries.insert(key.clone(), entry);
        }
        Ok(out)
    }

    /// Write the cache to a JSON file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing tune cache {path:?}"))
    }

    /// Load a cache file, strictly: any read/parse/schema failure is an
    /// error (use [`TuneCache::load_or_rebuild`] on the consult path).
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tune cache {path:?}"))?;
        Self::from_json(&Json::parse(&text)?)
            .with_context(|| format!("parsing tune cache {path:?}"))
    }

    /// The consult-path loader: a missing file is a normal cold start
    /// (empty cache, `rebuilt = false`); a present-but-corrupt or
    /// truncated file is **rejected and rebuilt** (empty cache,
    /// `rebuilt = true`) — never a panic, never garbage served as a
    /// schedule.
    pub fn load_or_rebuild(path: impl AsRef<Path>) -> (Self, bool) {
        let path = path.as_ref();
        if !path.exists() {
            return (Self::new(), false);
        }
        match Self::load(path) {
            Ok(cache) => (cache, false),
            Err(_) => (Self::new(), true),
        }
    }

    /// Strict-mode loader (`repro serve --verify` / `repro verify
    /// --tune-cache`): [`TuneCache::load_or_rebuild`] plus a full
    /// [`crate::verify`] static audit of every loaded entry. A cache that
    /// fails to parse *or* carries any Error-severity finding (an illegal
    /// schedule, an overflow-capable `gemm_k`, a nonsense runtime) is
    /// rejected and rebuilt exactly like a corrupt file; the returned
    /// [`Report`](crate::verify::Report) says why — parse failures become
    /// an `artifact-parse` finding so the refusal is always reportable.
    pub fn load_or_rebuild_verified(
        path: impl AsRef<Path>,
    ) -> (Self, bool, crate::verify::Report) {
        use crate::verify::{invariant, Finding, Report, Severity, Verifier};
        let path = path.as_ref();
        if !path.exists() {
            return (Self::new(), false, Report::new());
        }
        match Self::load(path) {
            Ok(cache) => {
                let report = Verifier::new().audit_tune_cache(&cache);
                if report.passed() {
                    (cache, false, report)
                } else {
                    (Self::new(), true, report)
                }
            }
            Err(e) => {
                let mut report = Report::new();
                report.push(Finding {
                    severity: Severity::Error,
                    invariant: invariant::ARTIFACT_PARSE,
                    artifact: format!("tune cache {path:?}"),
                    detail: format!("{e:#}"),
                });
                (Self::new(), true, report)
            }
        }
    }
}

/// A shareable handle on one [`TuneCache`]: sessions, the online tuner,
/// and the CLI all consult and update the same store through clones of
/// one handle, and [`CacheHandle::persist`] writes it back to its
/// backing file (if any) atomically with respect to other handle users.
#[derive(Clone)]
pub struct CacheHandle {
    inner: Arc<Mutex<TuneCache>>,
    path: Option<PathBuf>,
    rebuilt: bool,
}

impl std::fmt::Debug for CacheHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CacheHandle")
            .field("len", &self.len())
            .field("path", &self.path)
            .field("rebuilt", &self.rebuilt)
            .finish()
    }
}

impl CacheHandle {
    /// A process-local cache with no backing file ([`CacheHandle::persist`]
    /// is a no-op).
    pub fn in_memory() -> Self {
        Self { inner: Arc::new(Mutex::new(TuneCache::new())), path: None, rebuilt: false }
    }

    /// Open (or start) the cache at `path` via
    /// [`TuneCache::load_or_rebuild`] — corruption is absorbed, not
    /// propagated; check [`CacheHandle::was_rebuilt`] to report it.
    pub fn open(path: impl Into<PathBuf>) -> Self {
        let path = path.into();
        let (cache, rebuilt) = TuneCache::load_or_rebuild(&path);
        Self { inner: Arc::new(Mutex::new(cache)), path: Some(path), rebuilt }
    }

    /// Strict-mode [`CacheHandle::open`]: the file is additionally run
    /// through the [`crate::verify`] static analyzer
    /// ([`TuneCache::load_or_rebuild_verified`]), and a cache with any
    /// Error-severity finding opens empty-and-rebuilt. The findings
    /// report is returned alongside the handle so the caller can print
    /// why a cache was refused.
    pub fn open_verified(path: impl Into<PathBuf>) -> (Self, crate::verify::Report) {
        let path = path.into();
        let (cache, rebuilt, report) = TuneCache::load_or_rebuild_verified(&path);
        (Self { inner: Arc::new(Mutex::new(cache)), path: Some(path), rebuilt }, report)
    }

    /// Whether opening found a corrupt file and started fresh.
    pub fn was_rebuilt(&self) -> bool {
        self.rebuilt
    }

    /// The backing file, if any.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Entries currently cached.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// Whether the cache holds nothing.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }

    /// Exact-fingerprint lookup (cloned out of the shared store).
    pub fn lookup(&self, fp: &Fingerprint) -> Option<CacheEntry> {
        self.inner.lock().unwrap().lookup(fp).cloned()
    }

    /// Nearest warm-start donor for `fp` (see [`TuneCache::nearest`]).
    pub fn nearest(&self, fp: &Fingerprint) -> Option<(CacheEntry, u32)> {
        self.inner.lock().unwrap().nearest(fp).map(|(e, d)| (e.clone(), d))
    }

    /// File an entry (see [`TuneCache::insert`]).
    pub fn insert(&self, entry: CacheEntry) -> bool {
        self.inner.lock().unwrap().insert(entry)
    }

    /// GBT pretraining rows from the whole store.
    pub fn pretrain_rows(&self) -> Vec<(Vec<f64>, f64)> {
        self.inner.lock().unwrap().pretrain_rows()
    }

    /// A point-in-time copy of the store (for inspection and tests).
    pub fn snapshot(&self) -> TuneCache {
        self.inner.lock().unwrap().clone()
    }

    /// Write the store back to its backing file; a no-op for
    /// [`CacheHandle::in_memory`] handles.
    pub fn persist(&self) -> Result<()> {
        match &self.path {
            Some(path) => self.inner.lock().unwrap().save(path),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvWorkload;
    use crate::util::Rng;
    use crate::workload::MatmulWorkload;

    fn entry_for(wl: impl Into<OpWorkload>, runtime_us: f64, trials: usize) -> CacheEntry {
        CacheEntry {
            workload: wl.into(),
            config: ScheduleConfig { blk_row_warps: 1, warp_row_tiles: 1, ..Default::default() },
            runtime_us,
            trials,
            fidelity: "multi".to_string(),
            seed: 7,
            registry_version: REGISTRY_VERSION,
        }
    }

    #[test]
    fn anchoring_buckets_nearby_shapes_together() {
        // property: for seeded random conv shapes, two workloads whose
        // legality-GEMM dims share power-of-two buckets share an anchor
        // key, and shapes in different buckets never do
        let mut rng = Rng::new(11);
        for _ in 0..64 {
            let h = 7 + rng.gen_range(50);
            let c_in = 8 * (1 + rng.gen_range(64));
            let c_out = 8 * (1 + rng.gen_range(64));
            let a = ConvWorkload::new("fp_a", 1, h, h, c_in, c_out);
            let b = ConvWorkload::new("fp_b", 1, h, h, c_in, c_out);
            let fa = Fingerprint::of(&a.into());
            let fb = Fingerprint::of(&b.into());
            // identical shapes under different names: same bucket
            assert_eq!(fa.key(), fb.key());
            assert_eq!(fa.hash_key(), fb.hash_key());
            assert_eq!(fa.anchor_distance(&fb), Some(0));
        }
        // ragged shapes anchor up: stage2's M = 25088 buckets at 32768
        let big = ConvWorkload::resnet50_stage(2, 8);
        let m = big.gemm_m();
        let fa = Fingerprint::of(&big.clone().into());
        assert_eq!(fa.anchor.0, m.next_power_of_two());
        // different anchors -> different keys (never a silent merge)
        let small = ConvWorkload::resnet50_stage(5, 8);
        let fb = Fingerprint::of(&small.into());
        assert_ne!(fa.key(), fb.key());
        assert!(fa.anchor_distance(&fb).unwrap() > 0);
    }

    #[test]
    fn precisions_and_groups_never_collide() {
        let base = ConvWorkload::new("fp_p", 8, 28, 28, 64, 64);
        let f4 = Fingerprint::of(&base.clone().into());
        let f8 = Fingerprint::of(&base.clone().with_precision(Precision::Int8).into());
        assert_ne!(f4.key(), f8.key());
        assert_eq!(f4.anchor_distance(&f8), None, "no transfer across precisions");
        let fg = Fingerprint::of(&base.clone().with_groups(4).into());
        assert_ne!(f4.key(), fg.key());
        assert_eq!(f4.anchor_distance(&fg), None, "no transfer across groups");
        // operators are namespaced apart even on an identical GEMM
        let mm = MatmulWorkload::new("fp_mm", 6272, 64, 576);
        let fm = Fingerprint::of(&mm.into());
        assert_ne!(f4.key(), fm.key());
        assert_eq!(f4.anchor_distance(&fm), None);
    }

    #[test]
    fn insert_keeps_the_best_entry_per_bucket() {
        let mut cache = TuneCache::new();
        let wl = ConvWorkload::new("best", 8, 28, 28, 64, 64);
        assert!(cache.insert(entry_for(wl.clone(), 50.0, 32)));
        assert!(!cache.insert(entry_for(wl.clone(), 60.0, 64)), "slower never replaces");
        assert!(cache.insert(entry_for(wl.clone(), 40.0, 16)), "faster replaces");
        assert!(cache.insert(entry_for(wl.clone(), 40.0, 64)), "equal + more trials replaces");
        assert!(!cache.insert(entry_for(wl.clone(), 40.0, 64)), "identical does not");
        assert_eq!(cache.len(), 1);
        let fp = Fingerprint::of(&wl.into());
        assert_eq!(cache.lookup(&fp).unwrap().trials, 64);
    }

    #[test]
    fn nearest_prefers_the_closest_anchor_deterministically() {
        let mut cache = TuneCache::new();
        // three conv buckets at increasing channel widths
        cache.insert(entry_for(ConvWorkload::new("n64", 8, 28, 28, 64, 64), 10.0, 8));
        cache.insert(entry_for(ConvWorkload::new("n256", 8, 28, 28, 256, 256), 20.0, 8));
        let probe = Fingerprint::of(&ConvWorkload::new("probe", 8, 28, 28, 96, 96).into());
        assert!(cache.lookup(&probe).is_none(), "96 channels is its own bucket");
        let (donor, d) = cache.nearest(&probe).expect("same op/prec/groups exists");
        // 96's K axis (864 -> 1024) matches the 64-channel bucket's
        // (576 -> 1024) exactly; only N differs by one octave
        assert_eq!(donor.workload.name(), "n64");
        assert_eq!(d, 1);
        // a 192-channel probe sits on 256's side of every axis
        let probe2 = Fingerprint::of(&ConvWorkload::new("probe2", 8, 28, 28, 192, 192).into());
        let (donor2, d2) = cache.nearest(&probe2).unwrap();
        assert_eq!(donor2.workload.name(), "n256");
        assert_eq!(d2, 1);
        // a probe with no compatible entry gets nothing
        let mm = Fingerprint::of(&MatmulWorkload::new("probe_mm", 512, 512, 512).into());
        assert!(cache.nearest(&mm).is_none());
    }

    #[test]
    fn json_roundtrip_preserves_entries_and_provenance() {
        let mut cache = TuneCache::new();
        cache.insert(entry_for(ConvWorkload::resnet50_stage(2, 8), 51.25, 48));
        cache.insert(entry_for(MatmulWorkload::new("rt_mm", 1024, 768, 768), 99.5, 16));
        let text = cache.to_json().to_string();
        let back = TuneCache::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, cache);
        let (_, e) = back.iter().next().unwrap();
        assert_eq!(e.fidelity, "multi");
        assert_eq!(e.seed, 7);
        assert_eq!(e.registry_version, REGISTRY_VERSION);
    }

    #[test]
    fn corrupt_and_truncated_files_are_rejected_and_rebuilt() {
        let dir = std::env::temp_dir();
        let path = dir.join("tcconv_tunecache_corrupt_test.json");

        // a valid cache round-trips through disk
        let mut cache = TuneCache::new();
        cache.insert(entry_for(ConvWorkload::resnet50_stage(3, 8), 33.0, 24));
        cache.save(&path).unwrap();
        let (loaded, rebuilt) = TuneCache::load_or_rebuild(&path);
        assert_eq!(loaded, cache);
        assert!(!rebuilt);

        // truncation: chop the file mid-entry
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        let (empty, rebuilt) = TuneCache::load_or_rebuild(&path);
        assert!(empty.is_empty(), "truncated file must not serve partial garbage");
        assert!(rebuilt);

        // outright garbage
        std::fs::write(&path, "not json at all {{{").unwrap();
        let (empty, rebuilt) = TuneCache::load_or_rebuild(&path);
        assert!(empty.is_empty() && rebuilt);

        // wrong version is rejected by the strict loader too
        std::fs::write(&path, r#"{"version": 99, "entries": {}}"#).unwrap();
        assert!(TuneCache::load(&path).is_err());

        // an entry filed under a key its workload does not reproduce is
        // rejected (hand-edited / swapped entries must not serve)
        let mut honest = TuneCache::new();
        honest.insert(entry_for(ConvWorkload::resnet50_stage(3, 8), 33.0, 24));
        let honest_json = honest.to_json().to_string();
        let swapped = honest_json.replacen(":g1", ":g2", 1);
        assert_ne!(swapped, honest_json);
        std::fs::write(&path, swapped).unwrap();
        assert!(TuneCache::load(&path).is_err());
        let (empty, rebuilt) = TuneCache::load_or_rebuild(&path);
        assert!(empty.is_empty() && rebuilt);

        // a missing file is a cold start, not a rebuild
        std::fs::remove_file(&path).ok();
        let (cold, rebuilt) = TuneCache::load_or_rebuild(&path);
        assert!(cold.is_empty() && !rebuilt);
    }

    #[test]
    fn handle_shares_one_store_and_persists() {
        let path = std::env::temp_dir().join("tcconv_tunecache_handle_test.json");
        std::fs::remove_file(&path).ok();
        let handle = CacheHandle::open(&path);
        assert!(!handle.was_rebuilt());
        let clone = handle.clone();
        clone.insert(entry_for(ConvWorkload::resnet50_stage(4, 8), 12.0, 8));
        assert_eq!(handle.len(), 1, "clones share the store");
        handle.persist().unwrap();
        let reopened = CacheHandle::open(&path);
        assert_eq!(reopened.len(), 1);
        let fp = Fingerprint::of(&ConvWorkload::resnet50_stage(4, 8).into());
        assert!(reopened.lookup(&fp).is_some());
        std::fs::remove_file(&path).ok();
        // in-memory handles persist as a no-op
        let mem = CacheHandle::in_memory();
        mem.insert(entry_for(ConvWorkload::resnet50_stage(4, 8), 12.0, 8));
        mem.persist().unwrap();
        assert_eq!(mem.path(), None);
    }

    #[test]
    fn pretrain_rows_featurize_every_entry() {
        let mut cache = TuneCache::new();
        cache.insert(entry_for(ConvWorkload::resnet50_stage(2, 8), 51.0, 8));
        cache.insert(entry_for(MatmulWorkload::new("pre_mm", 1024, 768, 768), 88.0, 8));
        let rows = cache.pretrain_rows();
        assert_eq!(rows.len(), 2);
        for (x, y) in &rows {
            assert_eq!(x.len(), crate::costmodel::FEATURE_DIM);
            assert!(*y > 0.0);
        }
    }
}
