//! Quickstart: tune one convolution and inspect the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Tunes the ResNet50 stage-2 3x3 convolution (batch 8, the paper's
//! Table 1 target) with the diversity-aware explorer for 128 trials and
//! prints the best schedule, its simulated runtime, and the tuning curve.

use tcconv::conv::ConvWorkload;
use tcconv::explore::ExplorerKind;
use tcconv::tuner::{Tuner, TunerOptions};

fn main() {
    // 1. pick a workload: ResNet50 stage-2 3x3 conv, batch 8
    let wl = ConvWorkload::resnet50_stage(2, 8);
    println!(
        "workload: {} — {}x{}x{} conv, im2col GEMM {}x{}x{} ({:.2} GOPs)",
        wl.name,
        wl.height,
        wl.width,
        wl.in_channels,
        wl.gemm_m(),
        wl.gemm_n(),
        wl.gemm_k(),
        wl.ops() as f64 / 1e9
    );

    // 2. tune: 4 rounds of 32 measurements, diversity-aware exploration
    let mut tuner = Tuner::new(
        &wl,
        TunerOptions {
            n_trials: 128,
            explorer: ExplorerKind::DiversityAware,
            seed: 42,
            ..Default::default()
        },
    );
    let res = tuner.tune();

    // 3. results
    println!("\nbest schedule: {}", res.config.brief());
    println!(
        "simulated runtime: {:.2} us  ({:.1} GFLOPS)",
        res.runtime_us,
        wl.ops() as f64 / res.runtime_us / 1e3
    );
    println!("\ntuning curve (best-so-far, every 16 trials):");
    for r in res.history.records().iter().step_by(16) {
        println!(
            "  trial {:>4}: best {:>8.2} us   {}",
            r.trial,
            r.best_so_far_us,
            "#".repeat(((2000.0 / r.best_so_far_us) as usize).min(60))
        );
    }

    // 4. export for AOT baking: the schedule JSON round-trips into
    //    python/compile/schedules.py (aot.py --schedule-json)
    println!("\nschedule JSON: {}", res.config.to_json());
}
