//! Quickstart: tune one convolution with the `Session` API and inspect
//! the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Tunes the ResNet50 stage-2 3x3 convolution (batch 8, the paper's
//! Table 1 target) with the diversity-aware explorer for 128 trials and
//! prints the best schedule, its simulated runtime, the tuning curve, and
//! the schedule-registry entry a deployment would load.

use tcconv::conv::ConvWorkload;
use tcconv::registry::ScheduleRegistry;
use tcconv::tuner::Session;

fn main() {
    // 1. pick a workload: ResNet50 stage-2 3x3 conv, batch 8
    let wl = ConvWorkload::resnet50_stage(2, 8);
    println!(
        "workload: {} — {}x{}x{} conv, im2col GEMM {}x{}x{} ({:.2} GOPs)",
        wl.name,
        wl.height,
        wl.width,
        wl.in_channels,
        wl.gemm_m(),
        wl.gemm_n(),
        wl.gemm_k(),
        wl.ops() as f64 / 1e9
    );

    // 2. tune: 4 rounds of 32 measurements, diversity-aware exploration.
    //    (Everything is pluggable: .explorer(name) resolves through the
    //    explorer registry, .measurer(..) swaps the substrate.)
    let res = Session::for_workload(&wl)
        .trials(128)
        .seed(42)
        .explorer("diversity")
        .run()
        .expect("builtin explorer");

    // 3. results
    println!("\nbest schedule: {}", res.best.config.brief());
    println!(
        "simulated runtime: {:.2} us  ({:.1} GFLOPS)",
        res.best.runtime_us,
        wl.ops() as f64 / res.best.runtime_us / 1e3
    );
    println!("\ntuning curve (best-so-far, every 16 trials):");
    for r in res.best.history.records().iter().step_by(16) {
        println!(
            "  trial {:>4}: best {:>8.2} us   {}",
            r.trial,
            r.best_so_far_us,
            "#".repeat(((2000.0 / r.best_so_far_us) as usize).min(60))
        );
    }

    // 4. export: the bare schedule JSON round-trips into
    //    python/compile/schedules.py (aot.py --schedule-json), and the
    //    registry document is what `serve::Server::from_registry` routes
    //    requests with.
    println!("\nschedule JSON (aot.py --schedule-json): {}", res.best.config.to_json());
    let mut registry = ScheduleRegistry::new();
    registry.insert(&wl.name, res.registry_entry());
    println!("schedule registry JSON (repro serve --registry): {}", registry.to_json());
}
