//! Online serving demo: dynamic batching, registry hot-reload, and the
//! background re-tuner — the serve layer improving itself while it runs.
//!
//! ```bash
//! cargo run --release --example online_serving
//! WORKERS=8 REQUESTS=200 RETUNE_TRIALS=96 cargo run --release --example online_serving
//! ```
//!
//! The server starts with an **empty** registry (every kind runs under
//! the default fallback schedule), serves a burst of mixed-kind traffic,
//! and then an [`OnlineTuner`] reads the serve metrics, tunes the hot
//! schedule-less kinds with bounded warm-started sessions, and publishes
//! the winners via registry hot-reload. A second burst shows the same
//! kinds now executing under tuned schedules and a bumped snapshot
//! version — zero restarts, zero dropped requests.

use std::collections::HashMap;
use std::time::Instant;

use tcconv::conv::{ConvInstance, ConvWorkload};
use tcconv::quant::Epilogue;
use tcconv::serve::{Server, ServerConfig, SubmitError};
use tcconv::tuner::online::{OnlineTuner, RetunePolicy};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Push `n` requests round-robin over `kinds` and wait for every
/// response; returns (wall seconds, how many ran under a non-default
/// schedule, max registry version observed).
fn burst(server: &Server, kinds: &[ConvWorkload], n: usize, seed0: u64) -> (f64, usize, u64) {
    let epi = Epilogue::default();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        let wl = &kinds[i % kinds.len()];
        loop {
            match server.submit(&wl.name, ConvInstance::synthetic(wl, seed0 + i as u64), epi) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                Err(SubmitError::Busy) => std::thread::yield_now(),
                Err(e) => panic!("{e:?}"),
            }
        }
    }
    let default_schedule = tcconv::searchspace::ScheduleConfig::default();
    let mut tuned_hits = 0usize;
    let mut max_version = 0u64;
    for rx in pending {
        let r = rx.recv().expect("worker died");
        if r.schedule != default_schedule {
            tuned_hits += 1;
        }
        max_version = max_version.max(r.registry_version);
    }
    (t0.elapsed().as_secs_f64(), tuned_hits, max_version)
}

fn main() {
    let workers = env_usize("WORKERS", 4);
    let n_requests = env_usize("REQUESTS", 120);
    let retune_trials = env_usize("RETUNE_TRIALS", 64);

    // edge-inference conv kinds; small N keeps their legal spaces free of
    // the default schedule, so "tuned" is visible in the served schedule
    let kinds = vec![
        ConvWorkload::new("live_28x28", 1, 28, 28, 16, 8),
        ConvWorkload::new("live_14x14", 1, 14, 14, 32, 8),
        ConvWorkload::new("live_7x7", 1, 7, 7, 64, 8),
    ];

    println!("online serving demo: {workers} workers, {n_requests} requests/burst");
    let server = Server::start(ServerConfig {
        workers,
        queue_depth: 128,
        max_batch: 8,
        max_wait: 4, // hold underfull batches open 4 x 50 us for stragglers
    });
    println!(
        "server up with an EMPTY registry (snapshot v{}) — everything runs on the fallback schedule",
        server.registry_version()
    );

    // ---- burst 1: cold -----------------------------------------------------
    let (wall, tuned_hits, version) = burst(&server, &kinds, n_requests, 0);
    println!(
        "\nburst 1: {:.0} req/s | {tuned_hits}/{n_requests} tuned responses | snapshot v{version}",
        n_requests as f64 / wall
    );

    // ---- online re-tuning cycle -------------------------------------------
    println!("\nre-tuning hot schedule-less kinds ({retune_trials} trials each, warm-started):");
    let workloads: HashMap<String, ConvWorkload> =
        kinds.iter().map(|w| (w.name.clone(), w.clone())).collect();
    let mut tuner = OnlineTuner::new(
        workloads,
        RetunePolicy {
            trials: retune_trials,
            jobs: 2,                         // spare measurement workers
            max_kinds_per_cycle: kinds.len(),
            ..Default::default()
        },
    );
    let report = tuner.run_cycle(&server.handle()).expect("builtin explorer");
    for o in &report.outcomes {
        println!(
            "  {:<14} {:?} -> {:.2} us simulated, {}",
            o.kind,
            o.reason,
            o.tuned_runtime_us,
            if o.published { "published" } else { "not better, kept previous" }
        );
    }
    let v = report.published_version.expect("untuned kinds always publish");
    println!("registry hot-reloaded to snapshot v{v} — no restart, no dropped request");

    // ---- burst 2: warm -----------------------------------------------------
    let (wall, tuned_hits, version) = burst(&server, &kinds, n_requests, 1_000_000);
    println!(
        "\nburst 2: {:.0} req/s | {tuned_hits}/{n_requests} tuned responses | snapshot v{version}",
        n_requests as f64 / wall
    );
    assert_eq!(tuned_hits, n_requests, "every post-reload request runs tuned");

    let metrics = server.shutdown();
    println!("\nbatch-size histogram (requests coalesced per executed batch):");
    print!("{}", metrics.batch_histogram().render(40));
    println!("\nqueue-depth histogram (sampled at submit):");
    print!("{}", metrics.queue_depth_histogram().render(40));
    println!(
        "\n{} requests served across both bursts; per-worker completions: {:?}",
        metrics.total_count(),
        metrics.worker_counts()
    );
}
