//! Full Table-1 reproduction: tune the 3x3 convolution of every ResNet50
//! stage (2–5) and print the baseline / exhaustive / searched comparison.
//!
//! ```bash
//! cargo run --release --example resnet50_search            # 500 trials
//! TRIALS=160 cargo run --release --example resnet50_search # quicker
//! ```
//!
//! * **Baseline** — the best schedule the no-optimization template admits
//!   (TVM main-branch stand-in, itself tuned, as in §4.2).
//! * **Exhaustive** — minimum over every legal configuration of the full
//!   search space (the paper's manual exhaustive search).
//! * **Searched** — AutoTVM-style tuning with the diversity-aware
//!   explorer under the given trial budget.

use tcconv::report::{self, experiments};
use tcconv::sim::Simulator;

fn main() {
    let trials: usize = std::env::var("TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    let seed: u64 = std::env::var("SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);

    println!("ResNet50 3x3 conv schedule search — {trials} trials/conv, seed {seed}");
    let sim = Simulator { seed, ..Default::default() };
    let rows = experiments::run_table1(trials, seed, &sim);
    report::print_table1(&rows);

    println!("\npaper reference (NVIDIA T4, Table 1):");
    println!("  Baseline   196.06 180.96 203.62 198.62");
    println!("  Exhaustive  50.78  51.42  57.18  86.37");
    println!("  Searched    50.98  50.46  55.58  70.98");
    println!("  Speed-up     3.85x  3.59x  3.66x  2.80x");
    println!(
        "\nshape checks: searched ~= exhaustive on every stage; \
         stage5 (small H/W, many channels) gains least."
    );
}
