//! Serving demo: tune-time connected to serve-time. Each conv kind is
//! tuned with a quick `Session`, the best schedules land in a
//! `ScheduleRegistry`, and the L3 coordinator routes and batches
//! quantized-conv inference requests across a worker pool — executing
//! every request under its kind's tuned schedule.
//!
//! ```bash
//! cargo run --release --example serving
//! WORKERS=8 REQUESTS=200 TRIALS=96 cargo run --release --example serving
//! ```
//!
//! Workload: a mixed stream of edge-sized quantized convolutions (the
//! small-feature-map regime the paper's INT4 deployment targets), arriving
//! in bursts. Reports per-kind latency percentiles, batching behaviour and
//! sustained throughput, plus backpressure events under overload.

use std::time::Instant;

use tcconv::conv::{ConvInstance, ConvWorkload};
use tcconv::quant::Epilogue;
use tcconv::registry::ScheduleRegistry;
use tcconv::serve::{Server, ServerConfig, SubmitError};
use tcconv::sim::Simulator;
use tcconv::tuner::Session;
use tcconv::util::Rng;

fn main() {
    let workers: usize = std::env::var("WORKERS").ok().and_then(|v| v.parse().ok()).unwrap_or(4);
    let n_requests: usize =
        std::env::var("REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(120);
    let trials: usize =
        std::env::var("TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(96);

    // edge-inference conv kinds (INT4 domain)
    let kinds = vec![
        ("edge_28x28x32", ConvWorkload::new("edge_28x28x32", 1, 28, 28, 32, 32)),
        ("edge_14x14x64", ConvWorkload::new("edge_14x14x64", 1, 14, 14, 64, 64)),
        ("edge_7x7x128", ConvWorkload::new("edge_7x7x128", 1, 7, 7, 128, 128)),
    ];

    println!("serving demo: {workers} workers, {n_requests} requests, kinds:");
    for (k, wl) in &kinds {
        println!("  {k}: {}x{} C{}->{} ({:.1} MOPs)", wl.height, wl.width, wl.in_channels, wl.out_channels, wl.ops() as f64 / 1e6);
    }

    // tune each kind, persist the winners into the registry the server loads
    println!("\ntuning schedules ({trials} trials/kind):");
    let mut registry = ScheduleRegistry::new();
    for (kind, wl) in &kinds {
        let res = Session::for_workload(wl)
            .trials(trials)
            .measurer(Simulator::default().into_measurer())
            .run()
            .expect("builtin explorer");
        println!("  {kind}: {:.2} us  {}", res.best.runtime_us, res.best.config.brief());
        registry.insert(kind, res.registry_entry());
    }

    let server = Server::from_registry(
        ServerConfig { workers, queue_depth: 64, max_batch: 8, max_wait: 2 },
        registry,
    );
    let epi = Epilogue::default();
    let mut rng = Rng::new(7);
    let mut pending = Vec::new();
    let mut busy_events = 0usize;
    let t0 = Instant::now();

    let mut submitted = 0usize;
    while submitted < n_requests {
        // bursty arrivals: 1-8 requests per burst, same kind (spatial
        // locality of real traffic -> gives the batcher something to do)
        let burst = 1 + rng.gen_range(8);
        let (kind, wl) = &kinds[rng.gen_range(kinds.len())];
        for _ in 0..burst.min(n_requests - submitted) {
            let inst = ConvInstance::synthetic(wl, rng.next_u64());
            match server.submit(kind, inst, epi) {
                Ok(rx) => {
                    pending.push(rx);
                    submitted += 1;
                }
                Err(SubmitError::Busy) => {
                    busy_events += 1;
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                Err(e) => panic!("{e:?}"),
            }
        }
    }

    // collect all responses
    let mut total_batch = 0usize;
    let mut tuned_hits = 0usize;
    let default_schedule = tcconv::searchspace::ScheduleConfig::default();
    for rx in pending {
        let r = rx.recv().expect("worker died");
        total_batch += r.batch_size;
        if r.schedule != default_schedule {
            tuned_hits += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = server.shutdown();

    println!("\nper-kind latency (us):");
    println!(
        "{:<18} {:>6} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "kind", "n", "queue p50", "queue p95", "exec p50", "exec p95", "mean batch"
    );
    for kind in metrics.kinds() {
        let s = metrics.summary(&kind).unwrap();
        println!(
            "{:<18} {:>6} {:>12.0} {:>12.0} {:>12.0} {:>12.0} {:>10.2}",
            s.kind, s.count, s.queue_p50_us, s.queue_p95_us, s.exec_p50_us, s.exec_p95_us, s.mean_batch
        );
    }
    println!("\nend-to-end latency histogram (queue + exec):");
    print!("{}", metrics.total_latency_histogram().render(40));
    let counts = metrics.worker_counts();
    println!(
        "per-worker completions: [{}]",
        counts.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(", ")
    );
    println!(
        "\nthroughput: {:.0} requests/s over {:.2} s wall | mean co-batch {:.2} | backpressure events: {busy_events}",
        n_requests as f64 / wall,
        wall,
        total_batch as f64 / n_requests as f64,
    );
    println!(
        "{tuned_hits}/{n_requests} responses executed under a registry-tuned schedule"
    );
}
