//! Fig. 14 reproduction: the diversity-aware exploration module vs the
//! original AutoTVM simulated-annealing module, identical budgets.
//!
//! ```bash
//! cargo run --release --example diversity_ablation
//! TRIALS=256 SEEDS=5 cargo run --release --example diversity_ablation
//! ```
//!
//! Target convolution and setup per §4.3: ResNet50 stage-2 3x3 conv, the
//! *original AutoTVM search space* (tiling knobs only), best-found GFLOPS
//! as a function of measurement trials, averaged over seeds.

use tcconv::report::experiments;
use tcconv::sim::Simulator;

fn main() {
    let trials: usize = std::env::var("TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    let n_seeds: u64 = std::env::var("SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let seeds: Vec<u64> = (0..n_seeds).map(|i| 101 + 37 * i).collect();

    println!(
        "Fig. 14: diversity-aware vs original explorer — stage2 conv, \
         {trials} trials, mean of {n_seeds} seeds\n"
    );
    let sim = Simulator::default();
    let curves = experiments::run_fig14(trials, &seeds, &sim);

    let sa = experiments::mean_curve(&curves[0].1);
    let da = experiments::mean_curve(&curves[1].1);

    println!("{:>6} {:>16} {:>16}", "trial", curves[0].0, curves[1].0);
    let step = (trials / 16).max(1);
    for i in (0..sa.len()).step_by(step) {
        println!("{:>6} {:>15.1} {:>15.1}", sa[i].0, sa[i].1, da[i].1);
    }
    let last = sa.len() - 1;
    println!("{:>6} {:>15.1} {:>15.1}  <- final", sa[last].0, sa[last].1, da[last].1);

    let gain = (da[last].1 / sa[last].1 - 1.0) * 100.0;
    println!(
        "\ndiversity-aware final best: {gain:+.1}% GFLOPS vs original module \
         (paper: 'finds better performance configuration in the same trial')"
    );

    // per-seed finals, to show the spread
    println!("\nper-seed final best (us):");
    for (name, hs) in &curves {
        let finals: Vec<String> = hs
            .iter()
            .map(|h| format!("{:.1}", h.best_after(usize::MAX)))
            .collect();
        println!("  {name:<22} {}", finals.join("  "));
    }
}
