//! Network-level tuning: tune every distinct 3x3 conv of a whole model
//! (ResNet50 / ResNet18 / VGG16) with cross-stage transfer learning and
//! report per-layer and end-to-end speedup — the "convolution operations
//! of popular neural networks" of the paper's abstract.
//!
//! ```bash
//! cargo run --release --example network_tuning            # resnet18
//! MODEL=vgg16 TRIALS=256 cargo run --release --example network_tuning
//! OUT=schedules.json cargo run --release --example network_tuning
//! ```

use tcconv::registry::ScheduleRegistry;
use tcconv::searchspace::SpaceOptions;
use tcconv::sim::{SimMeasurer, Simulator};
use tcconv::tuner::{exhaustive_best, Session, SessionResult};
use tcconv::zoo;

fn main() {
    let model = std::env::var("MODEL").unwrap_or_else(|_| "resnet18".into());
    let trials: usize =
        std::env::var("TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(192);
    let net = zoo::by_name(&model, 8).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(1);
    });

    println!(
        "network tuning: {} (batch 8), {} distinct 3x3 convs, {:.1} GOPs/forward, {trials} trials/conv\n",
        net.name,
        net.layers.len(),
        net.total_ops() as f64 / 1e9
    );

    let sim = Simulator::default();
    println!(
        "{:<22} {:>4} {:>12} {:>12} {:>9}  schedule",
        "layer", "reps", "baseline us", "tuned us", "speedup"
    );
    let mut base_total = 0.0;
    let mut tuned_total = 0.0;
    let mut registry = ScheduleRegistry::new();
    // sessions chain: each layer warm-starts from the previous layer's
    // measurements (the workload-context features make them transferable)
    let mut prior: Option<SessionResult> = None;
    for l in &net.layers {
        let (_, base_us, _) = exhaustive_best(&l.workload, SpaceOptions::baseline(), &sim);
        let mut builder = Session::for_workload(&l.workload)
            .trials(trials)
            .measurer(SimMeasurer::boxed(sim.clone()));
        if let Some(p) = &prior {
            builder = builder.transfer_from(p);
        }
        let res = builder.run().expect("builtin explorer");
        base_total += base_us * l.repeats as f64;
        tuned_total += res.best.runtime_us * l.repeats as f64;
        println!(
            "{:<22} {:>4} {:>12.2} {:>12.2} {:>8.2}x  {}",
            l.workload.name(),
            l.repeats,
            base_us,
            res.best.runtime_us,
            base_us / res.best.runtime_us,
            res.best.config.brief()
        );
        registry.insert(&l.workload.kind(), res.registry_entry());
        prior = Some(res);
    }
    println!(
        "\n{} end-to-end 3x3-conv time: {:.1} us -> {:.1} us  ({:.2}x network-level speedup)",
        net.name,
        base_total,
        tuned_total,
        base_total / tuned_total
    );

    if let Ok(out) = std::env::var("OUT") {
        registry.save(&out).expect("writing registry");
        println!("schedule registry ({} entries) written to {out}", registry.len());
    }
}
