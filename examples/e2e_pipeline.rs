//! END-TO-END DRIVER — proves all three layers compose on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```
//!
//! Pipeline (this is the paper's full system, scaled to this testbed):
//!
//!  1. **Tune** (L3): for every ResNet50 stage conv, run the
//!     diversity-aware AutoTVM search on the T4 simulator and report the
//!     searched schedule + simulated speedup over the baseline template —
//!     the paper's headline metric.
//!  2. **Load** (runtime): load the AOT-compiled HLO artifacts (lowered
//!     once from the JAX/Pallas kernels at build time; python is NOT
//!     running now) onto the PJRT CPU client.
//!  3. **Serve** (L3 -> L1): execute a batch of quantized-conv inference
//!     requests through each compiled kernel, verify every output
//!     bit-exactly against the python oracle goldens, and report
//!     end-to-end latency/throughput of the serving path.

use std::path::PathBuf;
use std::time::Instant;

use tcconv::conv::ConvWorkload;
use tcconv::registry::ScheduleRegistry;
use tcconv::runtime::{read_golden, Engine};
use tcconv::searchspace::SpaceOptions;
use tcconv::sim::{SimMeasurer, Simulator};
use tcconv::tuner::{exhaustive_best, Session};

fn main() -> anyhow::Result<()> {
    let trials: usize = std::env::var("TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(192);
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    println!("=== e2e: tune -> load AOT artifacts -> serve + verify ===\n");

    // ---- phase 1: schedule search (simulated T4) ------------------------
    println!("[1/3] tuning schedules ({trials} trials per conv)");
    let sim = Simulator::default();
    let mut registry = ScheduleRegistry::new();
    let mut tuned = Vec::new();
    for stage in 2..=5 {
        let wl = ConvWorkload::resnet50_stage(stage, 8);
        let (_, base_us, _) = exhaustive_best(&wl, SpaceOptions::baseline(), &sim);
        let res = Session::for_workload(&wl)
            .trials(trials)
            .seed(stage as u64)
            .explorer("diversity")
            .measurer(SimMeasurer::boxed(sim.clone()))
            .run()?;
        println!(
            "  stage{stage}: {:>7.2} us (baseline {:>7.2} us, {:.2}x) {}",
            res.best.runtime_us,
            base_us,
            base_us / res.best.runtime_us,
            res.best.config.brief()
        );
        registry.insert(&wl.name, res.registry_entry());
        tuned.push((stage, res.best.clone()));
    }
    println!(
        "  schedule registry assembled ({} entries — what Server::from_registry serves with)",
        registry.len()
    );

    // ---- phase 2: load the AOT artifacts --------------------------------
    println!("\n[2/3] loading AOT artifacts via PJRT (python not involved)");
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            println!("  PJRT unavailable ({e}); skipping phases 2/3");
            return Ok(());
        }
    };
    println!("  PJRT platform: {}", engine.platform());
    let mut loaded = Vec::new();
    for stage in ["stage2", "stage3", "stage4", "stage5"] {
        let t = Instant::now();
        let conv = engine.load_conv(&artifacts, stage)?;
        println!(
            "  {stage}: compiled {:?} in {:.0} ms (gemm {}x{}x{}, schedule {})",
            conv.meta.hlo_path.file_name().unwrap(),
            t.elapsed().as_secs_f64() * 1e3,
            conv.meta.gemm.0,
            conv.meta.gemm.1,
            conv.meta.gemm.2,
            conv.meta.schedule.brief()
        );
        loaded.push(conv);
    }

    // ---- phase 3: serve requests + bit-exact verification ----------------
    println!("\n[3/3] serving quantized conv requests (CPU interpret-mode numerics)");
    let mut total_ops = 0u64;
    let mut total_s = 0.0f64;
    for conv in &loaded {
        let arrays = read_golden(&conv.meta.golden_path)?;
        let x: Vec<i8> = arrays[0].iter().map(|&b| b as i8).collect();
        let w: Vec<i8> = arrays[1].iter().map(|&b| b as i8).collect();
        let bias: Vec<i32> = arrays[2]
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let want: Vec<i32> = arrays[3]
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect();

        // warmup + timed runs
        let got = conv.run(&x, &w, &bias)?;
        anyhow::ensure!(got == want, "{}: output != python oracle", conv.meta.stage);
        let n_reqs = 1; // interpret-mode CPU numerics are slow; 1 timed request per conv
        let t = Instant::now();
        for _ in 0..n_reqs {
            let out = conv.run(&x, &w, &bias)?;
            std::hint::black_box(&out);
        }
        let dt = t.elapsed().as_secs_f64();
        total_ops += conv.meta.ops * n_reqs as u64;
        total_s += dt;
        println!(
            "  {}: bit-exact OK | {:.1} ms/request | {:.2} GOPS (CPU) | {} outputs",
            conv.meta.stage,
            dt / n_reqs as f64 * 1e3,
            conv.meta.ops as f64 * n_reqs as f64 / dt / 1e9,
            got.len()
        );
    }

    println!(
        "\nserving summary: {:.2} GOPS sustained on CPU PJRT across {} convs;",
        total_ops as f64 / total_s / 1e9,
        loaded.len()
    );
    println!("all outputs bit-exact vs the python/Pallas oracle — the three layers compose.");
    for (stage, res) in &tuned {
        println!(
            "  stage{stage} tuned schedule ready for AOT re-bake: {}",
            res.config.to_json()
        );
    }
    Ok(())
}
