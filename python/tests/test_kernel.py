"""L1 Pallas kernels vs the pure-jnp oracle — the CORE correctness signal.

Integer arithmetic end to end, so every comparison is exact equality.
Hypothesis sweeps the GEMM/conv shapes and the schedule knobs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_mma, pack, ref
from compile.schedules import MMA_K, MMA_M, MMA_N, Schedule


def rand_int4(key, shape, dtype=jnp.int8):
    return jax.random.randint(key, shape, -8, 8, dtype=dtype)


def gemm_case(seed, m, n, k):
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand_int4(kx, (m, k))
    w = rand_int4(kw, (k, n))
    bias = jax.random.randint(kb, (n,), -128, 128, dtype=jnp.int32)
    return x, w, bias


# --------------------------------------------------------------------------
# qgemm vs oracle
# --------------------------------------------------------------------------

SMALL = Schedule(1, 1, 1, 1, 1, 0)  # 8x8 blocks, K chunk 32


@pytest.mark.parametrize("pack_output", [True, False])
@pytest.mark.parametrize("relu", [True, False])
def test_qgemm_basic(pack_output, relu):
    x, w, bias = gemm_case(0, 32, 16, 64)
    got = conv_mma.qgemm(x, w, bias, SMALL, relu=relu, pack_output=pack_output)
    want = ref.qconv_gemm(x, w, bias, relu=relu, pack_output=pack_output)
    assert got.shape == want.shape
    assert (np.asarray(got) == np.asarray(want)).all()


schedule_strategy = st.builds(
    Schedule,
    blk_row_warps=st.sampled_from([1, 2]),
    blk_col_warps=st.sampled_from([1, 2]),
    warp_row_tiles=st.sampled_from([1, 2]),
    warp_col_tiles=st.sampled_from([1, 2]),
    chunk=st.sampled_from([1, 2]),
    reorder_inner=st.sampled_from([0, 1]),
)


@settings(max_examples=25, deadline=None)
@given(
    sched=schedule_strategy,
    mtiles=st.integers(1, 3),
    ntiles=st.integers(1, 3),
    ktiles=st.integers(1, 2),
    seed=st.integers(0, 2**16),
)
def test_qgemm_schedule_sweep(sched, mtiles, ntiles, ktiles, seed):
    """Every legal schedule computes the identical result: schedules change
    the walk, never the math."""
    m = sched.block_m * mtiles
    n = sched.block_n * ntiles
    k = sched.block_k * ktiles
    if n % pack.PACK_FACTOR or sched.block_n % pack.PACK_FACTOR:
        n = ((n + 7) // 8) * 8
    x, w, bias = gemm_case(seed, m, n, k)
    got = conv_mma.qgemm(x, w, bias, sched)
    want = ref.qconv_gemm(x, w, bias)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_qgemm_schedules_agree_with_each_other():
    """Two very different schedules -> bit-identical outputs."""
    x, w, bias = gemm_case(3, 64, 32, 128)
    a = conv_mma.qgemm(x, w, bias, Schedule(1, 1, 2, 2, 1, 0))
    b = conv_mma.qgemm(x, w, bias, Schedule(2, 2, 1, 1, 2, 1))
    assert (np.asarray(a) == np.asarray(b)).all()


def test_qgemm_rejects_illegal_schedule():
    x, w, bias = gemm_case(0, 24, 16, 32)  # M=24 not divisible by 16
    with pytest.raises(ValueError):
        conv_mma.qgemm(x, w, bias, Schedule(2, 1, 1, 1, 1, 0))


def test_qgemm_requant_shift_zero():
    x, w, bias = gemm_case(1, 16, 8, 32)
    got = conv_mma.qgemm(x, w, bias, SMALL, requant_shift=0)
    want = ref.qconv_gemm(x, w, bias, requant_shift=0)
    assert (np.asarray(got) == np.asarray(want)).all()


# --------------------------------------------------------------------------
# pack / unpack kernels
# --------------------------------------------------------------------------


def test_pack_kernel_matches_ref():
    key = jax.random.PRNGKey(7)
    x = jax.random.randint(key, (16, 64), -200, 200, dtype=jnp.int32)
    got = conv_mma.pack_int4_kernel(x)
    want = pack.pack_int4(pack.clip_int4(x))
    assert (np.asarray(got) == np.asarray(want)).all()


def test_unpack_kernel_roundtrip():
    key = jax.random.PRNGKey(8)
    vals = jax.random.randint(key, (8, 64), -8, 8, dtype=jnp.int32)
    packed = pack.pack_int4(vals)
    got = conv_mma.unpack_int4_kernel(packed)
    assert got.dtype == jnp.int8
    assert (np.asarray(got, dtype=np.int32) == np.asarray(vals)).all()


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 16, 24]),
    wtiles=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**16),
)
def test_pack_unpack_kernels_inverse(m, wtiles, seed):
    key = jax.random.PRNGKey(seed)
    n = 64 * wtiles
    vals = jax.random.randint(key, (m, n), -8, 8, dtype=jnp.int32)
    rt = conv_mma.unpack_int4_kernel(conv_mma.pack_int4_kernel(vals))
    assert (np.asarray(rt, dtype=np.int32) == np.asarray(vals)).all()


# --------------------------------------------------------------------------
# WMMA atom constants sanity (shared with the rust side)
# --------------------------------------------------------------------------


def test_mma_atom_matches_paper():
    # T4 INT4 MMA: 8x8 output atom, K-group 32 (8x32 operand, 2x the INT8
    # 8x16 operand — paper §1)
    assert (MMA_M, MMA_N, MMA_K) == (8, 8, 32)


def test_schedule_tile_arithmetic():
    s = Schedule(2, 4, 2, 1, 4, 0)
    assert s.block_m == 2 * 2 * 8
    assert s.block_n == 4 * 1 * 8
    assert s.block_k == 4 * 32
    assert s.threads_per_block == 2 * 4 * 32
    assert dataclasses.asdict(s)["chunk"] == 4
