"""AOT lowering: HLO text emission, schedule legalization, golden dumps."""

import dataclasses
import json
import os
import struct
import tempfile

import jax
import numpy as np
import pytest

from compile import aot, model
from compile.schedules import Schedule

TINY = model.ConvWorkload("resnet50_tinytest", 1, 8, 8, 32, 16)


def test_to_hlo_text_entry_computation():
    fn = model.make_stage_fn(TINY, Schedule(1, 1, 1, 1, 1, 0))
    x, w, bias = model.example_args(TINY)
    hlo = aot.to_hlo_text(jax.jit(fn).lower(x, w, bias))
    assert "ENTRY" in hlo
    assert "s32" in hlo  # integer pipeline
    # tuple return (rust unwraps with to_tuple1)
    assert "tuple" in hlo.lower()


def test_pick_schedule_legalizes_small_stage():
    # stage2 at batch 1: N(gemm)=64 -> block_n must divide 64
    wl = model.stage_by_name("stage2", batch=1)
    big = Schedule(8, 8, 8, 8, 8, 0)  # block 512x512, way too big
    s = aot.pick_schedule(wl, big)
    assert s.is_legal_for(wl.gemm_m, wl.gemm_n, wl.gemm_k)


def test_pick_schedule_keeps_legal_untouched():
    # stage3 at batch 1: gemm_m = 784 = 16 * 49, so block_m must be 8 or 16
    wl = model.stage_by_name("stage3", batch=1)
    s = Schedule(2, 2, 1, 2, 2, 0)  # block 16x32, chunk 64
    assert aot.pick_schedule(wl, s) == s


def test_golden_dump_format():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "g.bin")
        a = np.arange(6, dtype=np.int32).reshape(2, 3)
        b = np.arange(4, dtype=np.int8)
        aot._dump_golden(path, [a, b])
        with open(path, "rb") as f:
            raw = f.read()
        n0 = struct.unpack_from("<I", raw, 0)[0]
        assert n0 == 24
        assert raw[4 : 4 + 24] == a.tobytes()
        n1 = struct.unpack_from("<I", raw, 4 + 24)[0]
        assert n1 == 4
        assert raw[4 + 24 + 4 :] == b.tobytes()


@pytest.mark.slow
def test_build_stage_artifacts_end_to_end():
    """Full artifact build for a shrunken stage — exercises lowering, the
    kernel/oracle cross-check, and the meta schema the rust loader reads."""
    wl = dataclasses.replace(
        model.stage_by_name("stage2", batch=1), height=16, width=16,
        name="resnet50_stage2",
    )
    with tempfile.TemporaryDirectory() as d:
        meta = aot.build_stage_artifacts(wl, Schedule(1, 1, 1, 1, 1, 0), d)
        assert os.path.exists(os.path.join(d, "conv_stage2.hlo.txt"))
        assert os.path.exists(os.path.join(d, "golden_stage2.bin"))
        with open(os.path.join(d, "conv_stage2.meta.json")) as f:
            loaded = json.load(f)
        assert loaded == json.loads(json.dumps(meta, sort_keys=True))
        assert loaded["workload"]["gemm"] == [wl.gemm_m, wl.gemm_n, wl.gemm_k]
        assert loaded["output"]["dtype"] == "s32"
