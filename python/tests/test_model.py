"""L2 model: conv-as-GEMM pipeline, layouts, workloads, chained layers."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import pack, ref
from compile.schedules import Schedule

TINY = model.ConvWorkload("tiny", 1, 8, 8, 32, 32)
TINY_SCHED = Schedule(1, 1, 1, 1, 1, 0)


# --------------------------------------------------------------------------
# workload arithmetic (Table 1 invariants)
# --------------------------------------------------------------------------


def test_resnet50_stage_ops_match_table1():
    """All four stage convs have the paper's constant op count
    1,849,688,064 at batch 8."""
    for wl in model.resnet50_stage_convs(batch=8):
        assert wl.ops == 1_849_688_064, wl


def test_stage_gemm_dims():
    s2 = model.stage_by_name("stage2", batch=8)
    assert (s2.gemm_m, s2.gemm_n, s2.gemm_k) == (8 * 56 * 56, 64, 576)
    s5 = model.stage_by_name("stage5", batch=8)
    assert (s5.gemm_m, s5.gemm_n, s5.gemm_k) == (8 * 7 * 7, 512, 4608)


def test_same_padding_preserves_spatial():
    for wl in model.resnet50_stage_convs():
        assert (wl.out_height, wl.out_width) == (wl.height, wl.width)


# --------------------------------------------------------------------------
# im2col vs direct conv
# --------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 2),
    hw=st.sampled_from([4, 5, 8]),
    c=st.sampled_from([8, 16]),
    o=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_im2col_gemm_equals_direct_conv(n, hw, c, o, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(kx, (n, hw, hw, c), -8, 8, dtype=jnp.int8)
    w = jax.random.randint(kw, (3, 3, c, o), -8, 8, dtype=jnp.int8)
    cols = ref.im2col_nhwc(x, 3, 3, 1, 1)
    acc_gemm = ref.gemm_i32(cols, w.reshape(9 * c, o))
    acc_direct = ref.conv2d_int(x, w).reshape(-1, o)
    assert (np.asarray(acc_gemm) == np.asarray(acc_direct)).all()


def test_im2col_duplicate_structure():
    """Adjacent output pixels share kernel-window columns: row r at kernel
    col j+1 equals row r+1 at kernel col j (stride 1) — the §3.1 duplicates."""
    x = jnp.arange(1 * 6 * 6 * 2, dtype=jnp.int8).reshape(1, 6, 6, 2)
    cols = np.asarray(ref.im2col_nhwc(x, 3, 3, 1, 1))
    c = 2
    # output pixel (r=2, col=2) vs (r=2, col=3): window shifted by 1 in W.
    row_a = cols[2 * 6 + 2]
    row_b = cols[2 * 6 + 3]
    # kernel position (i, j) occupies block [(i*3+j)*c, (i*3+j+1)*c)
    for i in range(3):
        for j in range(2):
            a = row_a[(i * 3 + (j + 1)) * c : (i * 3 + j + 2) * c]
            b = row_b[(i * 3 + j) * c : (i * 3 + j + 1) * c]
            assert (a == b).all()


# --------------------------------------------------------------------------
# full conv fwd vs oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("pack_output", [True, False])
def test_qconv2d_fwd_matches_oracle(pack_output):
    x, w, bias = model.example_args(TINY)
    got = model.qconv2d_fwd(x, w, bias, TINY, TINY_SCHED, pack_output=pack_output)
    want = ref.qconv2d(x, w, bias, pack_output=pack_output)
    assert got.shape == want.shape
    assert (np.asarray(got) == np.asarray(want)).all()


def test_qconv2d_fwd_stage_shape_small_batch():
    wl = dataclasses.replace(
        model.stage_by_name("stage2", batch=1), height=16, width=16
    )
    x, w, bias = model.example_args(wl)
    y = model.qconv2d_fwd(x, w, bias, wl, TINY_SCHED)
    assert y.shape == (1, 16, 16, wl.out_channels // pack.PACK_FACTOR)


def test_qconv_chain_stays_in_int4_domain():
    wl = TINY
    x, w1, b1 = model.example_args(wl, seed=0)
    _, w2, b2 = model.example_args(wl, seed=1)
    y = model.qconv_chain_fwd(x, w1, b1, w2, b2, wl, TINY_SCHED)
    assert y.shape == (1, 8, 8, wl.out_channels // pack.PACK_FACTOR)
    vals = np.asarray(
        pack.unpack_int4(y.reshape(-1, y.shape[-1]))
    )
    assert vals.min() >= -8 and vals.max() <= 7


def test_qconv_chain_matches_composed_oracle():
    wl = TINY
    x, w1, b1 = model.example_args(wl, seed=0)
    _, w2, b2 = model.example_args(wl, seed=1)
    got = model.qconv_chain_fwd(x, w1, b1, w2, b2, wl, TINY_SCHED)
    y1 = ref.qconv2d(x, w1, b1, pack_output=False)
    y2 = ref.qconv2d(y1.astype(jnp.int8), w2, b2, pack_output=True)
    assert (np.asarray(got) == np.asarray(y2)).all()


# --------------------------------------------------------------------------
# NHWCnc layout
# --------------------------------------------------------------------------


def test_nhwcnc_roundtrip():
    x = jnp.arange(8 * 4 * 4 * 32, dtype=jnp.int8).reshape(8, 4, 4, 32)
    rt = model.nhwcnc_to_nhwc(model.nhwc_to_nhwcnc(x))
    assert (np.asarray(rt) == np.asarray(x)).all()


def test_nhwcnc_tile_is_contiguous_wmma_tile():
    """The two minor dims of NHWCnc are exactly one WMMA register tile:
    8 batch rows x 16 channel bytes."""
    x = jnp.arange(8 * 2 * 2 * 16, dtype=jnp.int8).reshape(8, 2, 2, 16)
    t = model.nhwc_to_nhwcnc(x)
    assert t.shape == (1, 2, 2, 1, 8, 16)
    tile = np.asarray(t)[0, 1, 0, 0]
    want = np.asarray(x)[:, 1, 0, :]
    assert (tile == want).all()


def test_nhwcnc_rejects_bad_shapes():
    with pytest.raises(ValueError):
        model.nhwc_to_nhwcnc(jnp.zeros((3, 4, 4, 16), jnp.int8))
    with pytest.raises(ValueError):
        model.nhwc_to_nhwcnc(jnp.zeros((8, 4, 4, 12), jnp.int8))
