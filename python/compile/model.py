"""L2: the JAX compute graph — quantized convolutions of ResNet50's 3x3
stage layers, built on the L1 Pallas kernels.

The paper evaluates the 3x3 spatial convolutions of each ResNet50 stage at
batch 8 (Table 1: OPs = 1,849,688,064 = 2 * 8 * H * W * 3*3 * C * O for
every stage — constant because each stage halves H/W and doubles C/O).

This module is build-time only: ``aot.py`` lowers the jitted functions here
to HLO text once, and the rust coordinator executes the artifacts via PJRT.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import conv_mma, pack, ref
from .schedules import Schedule


@dataclasses.dataclass(frozen=True)
class ConvWorkload:
    """High-level convolution definition (mirrors ``rust/src/conv``)."""

    name: str
    batch: int
    height: int
    width: int
    in_channels: int
    out_channels: int
    kernel: int = 3
    stride: int = 1
    padding: int = 1

    @property
    def out_height(self) -> int:
        return (self.height + 2 * self.padding - self.kernel) // self.stride + 1

    @property
    def out_width(self) -> int:
        return (self.width + 2 * self.padding - self.kernel) // self.stride + 1

    # im2col GEMM dimensions (paper §2.1):
    #   M = N*OH*OW rows, N = O columns, K = KH*KW*I accumulation.
    @property
    def gemm_m(self) -> int:
        return self.batch * self.out_height * self.out_width

    @property
    def gemm_n(self) -> int:
        return self.out_channels

    @property
    def gemm_k(self) -> int:
        return self.kernel * self.kernel * self.in_channels

    @property
    def ops(self) -> int:
        """Multiply-accumulate op count (2 ops per MAC), Table 1's OPs row."""
        return 2 * self.gemm_m * self.gemm_n * self.gemm_k

    def x_shape(self) -> tuple[int, int, int, int]:
        return (self.batch, self.height, self.width, self.in_channels)

    def w_shape(self) -> tuple[int, int, int, int]:
        return (self.kernel, self.kernel, self.in_channels, self.out_channels)


def resnet50_stage_convs(batch: int = 8) -> list[ConvWorkload]:
    """The four target convolutions of Table 1: the 3x3 conv of each
    residual stage.  Feature size halves and channels double per stage, so
    the op count is constant."""
    return [
        ConvWorkload("resnet50_stage2", batch, 56, 56, 64, 64),
        ConvWorkload("resnet50_stage3", batch, 28, 28, 128, 128),
        ConvWorkload("resnet50_stage4", batch, 14, 14, 256, 256),
        ConvWorkload("resnet50_stage5", batch, 7, 7, 512, 512),
    ]


def stage_by_name(name: str, batch: int = 8) -> ConvWorkload:
    for w in resnet50_stage_convs(batch):
        if w.name == name or w.name.endswith(name):
            return w
    raise KeyError(name)


# ---------------------------------------------------------------------------
# layout: NHWC <-> NHWCnc (paper §3.3)
# ---------------------------------------------------------------------------

WMMA_N_ROWS = 8  # 'n' of NHWCnc: WMMA register-tile row count
WMMA_C_COLS = 16  # 'c' of NHWCnc: WMMA register-tile column count (16B lane)


def nhwc_to_nhwcnc(x: jnp.ndarray) -> jnp.ndarray:
    """Reshape NHWC into the NHWCnc tiled layout the paper stores globally
    so WMMA-tile loads coalesce: split batch into n-tiles of 8 and channels
    into c-tiles of 16, moving both to the minor dimensions.

    (N, H, W, C) -> (N/8, H, W, C/16, 8, 16)
    """
    n, h, w, c = x.shape
    if n % WMMA_N_ROWS or c % WMMA_C_COLS:
        raise ValueError(f"NHWCnc needs N%{WMMA_N_ROWS}==0, C%{WMMA_C_COLS}==0")
    return (
        x.reshape(n // WMMA_N_ROWS, WMMA_N_ROWS, h, w, c // WMMA_C_COLS, WMMA_C_COLS)
        .transpose(0, 2, 3, 4, 1, 5)
    )


def nhwcnc_to_nhwc(x: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`nhwc_to_nhwcnc`."""
    nt, h, w, ct, nr, cc = x.shape
    return (
        x.transpose(0, 4, 1, 2, 3, 5)
        .reshape(nt * nr, h, w, ct * cc)
    )


# ---------------------------------------------------------------------------
# the conv forward pass
# ---------------------------------------------------------------------------


def qconv2d_fwd(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    wl: ConvWorkload,
    schedule: Schedule | None = None,
    *,
    relu: bool = True,
    requant_shift: int = 6,
    pack_output: bool = True,
) -> jnp.ndarray:
    """Quantized conv forward: im2col lowering -> Pallas MMA GEMM kernel
    with fused epilogue + packing -> spatial reshape.

    x: (N, H, W, C) int8 (INT4-valued), w: (KH, KW, C, O) int8,
    bias: (O,) int32.
    Returns (N, OH, OW, O/8) int32 packed (or (N, OH, OW, O) int32).
    """
    cols = ref.im2col_nhwc(x, wl.kernel, wl.kernel, wl.stride, wl.padding)
    wmat = w.reshape(wl.gemm_k, wl.gemm_n)
    out = conv_mma.qgemm(
        cols,
        wmat,
        bias,
        schedule,
        relu=relu,
        requant_shift=requant_shift,
        pack_output=pack_output,
    )
    o = wl.gemm_n // (pack.PACK_FACTOR if pack_output else 1)
    return out.reshape(wl.batch, wl.out_height, wl.out_width, o)


def qconv_chain_fwd(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    wl: ConvWorkload,
    schedule: Schedule | None = None,
    *,
    requant_shift: int = 6,
) -> jnp.ndarray:
    """Two chained quantized convs (the layout-consistency scenario of
    §3.3: layer L's packed output is layer L+1's input).  The intermediate
    stays in the INT4 domain; the unpack at the boundary is the 'single
    extra warp shuffle' of the paper, expressed as the unpack kernel."""
    y1 = qconv2d_fwd(
        x, w1, b1, wl, schedule, requant_shift=requant_shift, pack_output=True
    )
    n, oh, ow, wpk = y1.shape
    y1_unpacked = conv_mma.unpack_int4_kernel(
        y1.reshape(n * oh * ow, wpk)
    ).reshape(n, oh, ow, wpk * pack.PACK_FACTOR)
    wl2 = dataclasses.replace(
        wl,
        height=wl.out_height,
        width=wl.out_width,
        in_channels=wpk * pack.PACK_FACTOR,
    )
    return qconv2d_fwd(
        y1_unpacked, w2, b2, wl2, schedule,
        requant_shift=requant_shift, pack_output=True,
    )


def make_stage_fn(
    wl: ConvWorkload,
    schedule: Schedule | None = None,
    *,
    pack_output: bool = True,
) -> Callable:
    """Build the jit-able per-stage function AOT lowers.  Returns a 1-tuple
    (the rust loader unwraps with ``to_tuple1``)."""

    def fn(x, w, bias):
        return (
            qconv2d_fwd(x, w, bias, wl, schedule, pack_output=pack_output),
        )

    return fn


def example_args(wl: ConvWorkload, seed: int = 0):
    """Deterministic INT4-domain sample inputs for lowering and goldens."""
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.randint(kx, wl.x_shape(), -8, 8, dtype=jnp.int8)
    w = jax.random.randint(kw, wl.w_shape(), -8, 8, dtype=jnp.int8)
    bias = jax.random.randint(kb, (wl.out_channels,), -64, 64, dtype=jnp.int32)
    return x, w, bias
