"""L1 perf profile: VMEM footprint + MXU-utilization *estimates* per
schedule (DESIGN.md §Perf L1).

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the L1
optimization target is structural: do the blocks fit VMEM comfortably, is
the contraction MXU-shaped, how much of the staged data is compute-useful.

Run: cd python && python -m compile.vmem_report
"""

from __future__ import annotations

from . import model
from .schedules import Schedule, MMA_K

# TPU-ish envelope used for the estimates (the repo's CPU runs interpret
# mode; these numbers contextualize the BlockSpec choices, DESIGN.md
# §Hardware-Adaptation).
VMEM_BYTES = 16 * 2**20
MXU_DIM = 128


def block_vmem_bytes(s: Schedule, dtype_bytes: int = 1, acc_bytes: int = 4) -> int:
    """Resident bytes for one grid step of the qgemm kernel: x tile +
    w tile + bias + accumulator scratch + packed output tile."""
    bm, bn, bk = s.block_m, s.block_n, s.block_k
    x = bm * bk * dtype_bytes
    w = bk * bn * dtype_bytes
    bias = bn * 4
    acc = bm * bn * acc_bytes
    out = bm * (bn // 8) * 4
    return x + w + bias + acc + out


def mxu_utilization(s: Schedule) -> float:
    """Fraction of an MXU_DIM x MXU_DIM systolic pass the block tile
    fills (both operand dims), per K-group."""
    fill_m = min(s.block_m, MXU_DIM) / MXU_DIM
    fill_n = min(s.block_n, MXU_DIM) / MXU_DIM
    fill_k = min(s.block_k, MXU_DIM) / MXU_DIM
    return fill_m * fill_n * fill_k


def main() -> None:
    print(f"L1 structural profile (VMEM budget {VMEM_BYTES >> 20} MiB, MXU {MXU_DIM}x{MXU_DIM})")
    print(f"{'stage':<8} {'schedule (bm,bn,bk)':<22} {'VMEM/step':>10} {'fit':>5} "
          f"{'MXU fill':>9} {'K%{}'.format(MMA_K):>6}")
    from .aot import pick_schedule

    for wl in model.resnet50_stage_convs(batch=8):
        s = pick_schedule(wl, Schedule())
        vmem = block_vmem_bytes(s)
        print(
            f"{wl.name.replace('resnet50_', ''):<8} "
            f"({s.block_m:>3},{s.block_n:>3},{s.block_k:>3}){'':<8} "
            f"{vmem:>9}B {'ok' if vmem < VMEM_BYTES else 'NO':>5} "
            f"{mxu_utilization(s):>8.2f} "
            f"{'yes' if s.block_k % MMA_K == 0 else 'no':>6}"
        )
    print("\nlarger tiles raise MXU fill until VMEM double-buffering caps them;")
    print("the rust-side tuner explores exactly this trade on the T4 cost model.")


if __name__ == "__main__":
    main()
