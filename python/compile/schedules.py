"""Schedule (configuration) definition — the tuning knobs of the paper.

This mirrors ``rust/src/searchspace/config.rs`` one-to-one.  A Schedule fixes
how the im2col GEMM of a reduced-precision convolution is tiled onto the
Tensor-Core-style execution hierarchy:

    output matrix (M x N)
      -> thread-block tiles   (block_m x block_n)
        -> warp tiles         (warp_m  x warp_n)
          -> WMMA atoms       (MMA_M   x MMA_N)   with K-group MMA_K

Knobs (paper §4.1):
  blk_row_warps   warps along M per thread block      (BLK-ROW-WARPS)
  blk_col_warps   warps along N per thread block      (BLK-COL-WARPS)
  warp_row_tiles  WMMA tiles along M per warp         (WARP-ROW-TILES)
  warp_col_tiles  WMMA tiles along N per warp         (WARP-COL-TILES)
  chunk           K-loop split factor                 (CHUNK)
  reorder_inner   loop order: channel-outer vs KH     (REORDER-INNER)

Optimization flags (paper §3.1-3.3, the ablation axes of Fig. 15/16):
  dup_aware       duplicate-aware feature-map load
  reg_packing     register-level epilogue + INT4 output packing
  nhwcnc_layout   NHWCnc global layout for coalesced WMMA loads
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterator

# WMMA atom for INT4 MMA on Tensor Cores (paper §1: T4 INT4 MMA takes an
# 8x32 K-group; the atomic output tile is 8x8).
MMA_M = 8
MMA_N = 8
MMA_K = 32

# INT8 MMA halves the K-group (8x16 operand).
MMA_K_INT8 = 16

KNOB_VALUES = {
    "blk_row_warps": (1, 2, 4, 8),
    "blk_col_warps": (1, 2, 4, 8),
    "warp_row_tiles": (1, 2, 4, 8),
    "warp_col_tiles": (1, 2, 4, 8),
    "chunk": (1, 2, 4, 8),
    "reorder_inner": (0, 1),
}


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One point of the search space.  Immutable and hashable."""

    blk_row_warps: int = 2
    blk_col_warps: int = 2
    warp_row_tiles: int = 2
    warp_col_tiles: int = 2
    chunk: int = 2
    reorder_inner: int = 0
    # optimization flags
    dup_aware: bool = True
    reg_packing: bool = True
    nhwcnc_layout: bool = True

    # --- derived tile geometry ------------------------------------------
    @property
    def warp_m(self) -> int:
        return self.warp_row_tiles * MMA_M

    @property
    def warp_n(self) -> int:
        return self.warp_col_tiles * MMA_N

    @property
    def block_m(self) -> int:
        return self.blk_row_warps * self.warp_m

    @property
    def block_n(self) -> int:
        return self.blk_col_warps * self.warp_n

    @property
    def block_k(self) -> int:
        return self.chunk * MMA_K

    @property
    def warps_per_block(self) -> int:
        return self.blk_row_warps * self.blk_col_warps

    @property
    def threads_per_block(self) -> int:
        return self.warps_per_block * 32

    # --- legality --------------------------------------------------------
    def is_legal_for(self, m: int, n: int, k: int) -> bool:
        """A schedule is legal for an (M, N, K) GEMM iff the tile hierarchy
        divides the problem exactly (the paper pads im2col M to a multiple of
        the block; we require divisibility like the TVM template does)."""
        return (
            m % self.block_m == 0
            and n % self.block_n == 0
            and k % self.block_k == 0
        )

    # --- serde (interchange with the rust coordinator) -------------------
    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "Schedule":
        return Schedule(**json.loads(text))


def enumerate_schedules(
    m: int, n: int, k: int, *, legal_only: bool = True
) -> Iterator[Schedule]:
    """Enumerate the knob cross-product (optionally restricted to legal
    schedules for an (M, N, K) problem).  Optimization flags are held at
    their defaults; the rust side owns the full 8-dimensional walk."""
    for brw in KNOB_VALUES["blk_row_warps"]:
        for bcw in KNOB_VALUES["blk_col_warps"]:
            for wrt in KNOB_VALUES["warp_row_tiles"]:
                for wct in KNOB_VALUES["warp_col_tiles"]:
                    for ch in KNOB_VALUES["chunk"]:
                        for ro in KNOB_VALUES["reorder_inner"]:
                            s = Schedule(brw, bcw, wrt, wct, ch, ro)
                            if not legal_only or s.is_legal_for(m, n, k):
                                yield s


# Default schedule used for AOT artifacts when no tuned schedule is supplied.
DEFAULT_SCHEDULE = Schedule()
