"""INT4 <-> int32 register packing, expressed as jnp bit ops.

Paper §3.2: after the epilogue (bias/BN/ReLU), the INT4 outputs are clipped
and packed eight-per-32-bit-register *before* the shared-memory store.  On
Tensor Cores this is done with warp shuffles; here the same bit layout is
produced with vectorized integer ops so it lowers into the AOT HLO.  The
rust substrate (``rust/src/quant``) implements the identical layout
bit-exactly (lane-by-lane warp-shuffle emulation) and the two are checked
against each other through golden vectors (``python/tests/golden_pack``).

Bit layout (matches NVIDIA's packed-s4 convention): element ``j`` of a group
of 8 occupies bits ``[4*j, 4*j+4)`` of the int32 word, two's-complement.
"""

from __future__ import annotations

import jax.numpy as jnp

INT4_MIN = -8
INT4_MAX = 7
PACK_FACTOR = 8  # int4 values per int32 word


def clip_int4(x: jnp.ndarray) -> jnp.ndarray:
    """Clip/saturate to the signed 4-bit range (paper: 'clipped to lower
    bits')."""
    return jnp.clip(x, INT4_MIN, INT4_MAX)


def pack_int4(x: jnp.ndarray) -> jnp.ndarray:
    """Pack the last axis (length divisible by 8) of int32 values already in
    [-8, 7] into int32 words, 8 per word.

    x: (..., L) int32  ->  (..., L // 8) int32
    """
    if x.shape[-1] % PACK_FACTOR != 0:
        raise ValueError(
            f"last axis {x.shape[-1]} not divisible by {PACK_FACTOR}"
        )
    g = x.reshape(*x.shape[:-1], x.shape[-1] // PACK_FACTOR, PACK_FACTOR)
    nibbles = jnp.bitwise_and(g.astype(jnp.int32), 0xF)
    shifts = jnp.arange(PACK_FACTOR, dtype=jnp.int32) * 4
    # The shifted nibbles occupy disjoint bit ranges, so their wrapping sum
    # is exactly their bitwise OR (no carries) — and sum lowers to a single
    # reduce, which XLA fuses better than a chain of ORs.
    return jnp.sum(
        jnp.left_shift(nibbles, shifts), axis=-1, dtype=jnp.int32
    )


def unpack_int4(p: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_int4` with sign extension.

    p: (..., W) int32  ->  (..., W * 8) int32 in [-8, 7]
    """
    shifts = jnp.arange(PACK_FACTOR, dtype=jnp.int32) * 4
    nib = jnp.bitwise_and(
        jnp.right_shift(p[..., None], shifts), 0xF
    ).astype(jnp.int32)
    # sign-extend 4-bit two's complement
    nib = jnp.where(nib >= 8, nib - 16, nib)
    return nib.reshape(*p.shape[:-1], p.shape[-1] * PACK_FACTOR)


def requantize(acc: jnp.ndarray, shift: int) -> jnp.ndarray:
    """Requantize an int32 accumulator back to the INT4 domain with a
    power-of-two scale (arithmetic right shift with round-to-nearest-even
    tie-away avoided: we use round-half-up which matches the rust side),
    then saturate.

    This is the integer-only epilogue of HAWQ-V3-style inference the paper
    assumes ('integer-only inference without any floating point').
    """
    if shift < 0:
        raise ValueError("shift must be >= 0")
    if shift == 0:
        return clip_int4(acc)
    rounding = jnp.int32(1 << (shift - 1))
    return clip_int4(jnp.right_shift(acc + rounding, shift))
