"""L1 Pallas kernel: schedule-parameterized reduced-precision GEMM with
fused epilogue and INT4 output packing.

This is the compute hot-spot of the paper — the im2col GEMM of a quantized
convolution, tiled onto an MMA execution hierarchy.  The schedule knobs of
the search space (``schedules.Schedule``) map directly onto the Pallas grid
and BlockSpecs:

    block_m = BLK_ROW_WARPS * WARP_ROW_TILES * 8   -> out_spec block rows
    block_n = BLK_COL_WARPS * WARP_COL_TILES * 8   -> out_spec block cols
    block_k = CHUNK * 32                           -> K-grid step
    reorder_inner                                  -> grid axis order (K-major
                                                      vs N-major inner loop)

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA kernel's
threadblock/warp decomposition becomes the Pallas grid + block shape; the
shared-memory staging the paper tunes becomes the HBM->VMEM schedule the
BlockSpecs express; warp-shuffle packing becomes vectorized bit ops on the
register tile.  Kernels are lowered with ``interpret=True`` (CPU PJRT cannot
run Mosaic custom-calls) — structure, not CPU wallclock, is what the
schedule controls; the rust simulator models the T4-side cost.

All arithmetic is integer, so kernel-vs-ref checks are exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import pack
from ..schedules import Schedule

INTERPRET = True  # CPU PJRT: Mosaic lowering unavailable (see module doc)


def _gemm_kernel(
    x_ref, w_ref, bias_ref, o_ref, acc_ref, *, nk: int, relu: bool,
    requant_shift: int, pack_output: bool
):
    """One (block_m x block_n) output tile; grid axis 2 walks K chunks.

    The accumulator lives in scratch across the K walk (the paper's
    register-tile accumulator); the epilogue + packing run on the final K
    step *before* the tile is stored (paper §3.2.2: epilogue reordered ahead
    of the shared-memory store).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        out = acc_ref[...] + bias_ref[...].astype(jnp.int32)[None, :]
        if relu:
            out = jnp.maximum(out, 0)
        out = pack.requantize(out, requant_shift)
        if pack_output:
            o_ref[...] = pack.pack_int4(out)
        else:
            o_ref[...] = out


def qgemm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    schedule: Schedule | None = None,
    *,
    relu: bool = True,
    requant_shift: int = 6,
    pack_output: bool = True,
) -> jnp.ndarray:
    """Reduced-precision GEMM + epilogue + packing as one Pallas kernel.

    x: (M, K) int8 (values in the INT4 domain [-8, 7])
    w: (K, N) int8
    bias: (N,) int32
    -> (M, N // 8) int32 packed, or (M, N) int32 when ``pack_output=False``.
    """
    schedule = schedule or Schedule()
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    bm, bn, bk = schedule.block_m, schedule.block_n, schedule.block_k
    if not schedule.is_legal_for(m, n, k):
        raise ValueError(
            f"schedule {schedule} illegal for GEMM ({m}, {n}, {k}): "
            f"tiles ({bm}, {bn}, {bk}) must divide the problem"
        )
    nk = k // bk
    pack_div = pack.PACK_FACTOR if pack_output else 1
    out_cols = n // pack_div
    bn_out = bn // pack_div
    if pack_output and bn % pack.PACK_FACTOR != 0:
        raise ValueError(f"block_n {bn} not divisible by pack factor")

    kernel = functools.partial(
        _gemm_kernel,
        nk=nk,
        relu=relu,
        requant_shift=requant_shift,
        pack_output=pack_output,
    )
    # REORDER_INNER: axis order of the sequential grid walk.  0 = K
    # innermost (channel chunks swept inside an output tile — best reuse of
    # the accumulator); 1 = N innermost (kernel-height-style sweep).  Both
    # orders are legal because the accumulator scratch persists across grid
    # steps of the same output tile only when K is innermost; for the
    # reordered variant we keep K innermost in the grid but swap the M/N
    # walk, which is the component of the loop order observable at the
    # Pallas level.
    if schedule.reorder_inner:
        grid = (n // bn, m // bm, nk)
        x_spec = pl.BlockSpec((bm, bk), lambda j, i, kk: (i, kk))
        w_spec = pl.BlockSpec((bk, bn), lambda j, i, kk: (kk, j))
        b_spec = pl.BlockSpec((bn,), lambda j, i, kk: (j,))
        o_spec = pl.BlockSpec((bm, bn_out), lambda j, i, kk: (i, j))
    else:
        grid = (m // bm, n // bn, nk)
        x_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
        w_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
        b_spec = pl.BlockSpec((bn,), lambda i, j, kk: (j,))
        o_spec = pl.BlockSpec((bm, bn_out), lambda i, j, kk: (i, j))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, w_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, out_cols), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=INTERPRET,
    )(x, w, bias)


def _pack_kernel(x_ref, o_ref):
    """Standalone INT4 packing kernel (paper Fig. 9): clip a tile of int32
    values to the INT4 domain and pack 8-per-word along the last axis."""
    o_ref[...] = pack.pack_int4(pack.clip_int4(x_ref[...]))


def _largest_divisor(n: int, cap: int, multiple_of: int = 1) -> int:
    """Largest d <= cap with d | n and multiple_of | d (>= multiple_of)."""
    for d in range(min(cap, n), multiple_of - 1, -1):
        if n % d == 0 and d % multiple_of == 0:
            return d
    return multiple_of


def pack_int4_kernel(
    x: jnp.ndarray, *, block_m: int | None = None, block_n: int | None = None
) -> jnp.ndarray:
    """Pallas version of the register-level packing step, usable on its own
    (e.g. to re-pack activations between layers when the producer did not
    fuse packing).  x: (M, N) int32 -> (M, N // 8) int32."""
    m, n = x.shape
    block_m = block_m or _largest_divisor(m, 8)
    block_n = block_n or _largest_divisor(n, 64, pack.PACK_FACTOR)
    if m % block_m or n % block_n or block_n % pack.PACK_FACTOR:
        raise ValueError(f"bad pack tiling for ({m}, {n})")
    return pl.pallas_call(
        _pack_kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec(
            (block_m, block_n // pack.PACK_FACTOR), lambda i, j: (i, j)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (m, n // pack.PACK_FACTOR), jnp.int32
        ),
        interpret=INTERPRET,
    )(x)


def _unpack_kernel(x_ref, o_ref):
    o_ref[...] = pack.unpack_int4(x_ref[...]).astype(jnp.int8)


def unpack_int4_kernel(
    x: jnp.ndarray, *, block_m: int | None = None, block_n: int | None = None
) -> jnp.ndarray:
    """Inverse packing kernel: (M, W) int32 -> (M, W * 8) int8 in [-8, 7].
    Used at layer boundaries when the consumer needs unpacked operands."""
    m, w = x.shape
    block_m = block_m or _largest_divisor(m, 8)
    block_n = block_n or _largest_divisor(w, 8)
    if m % block_m or w % block_n:
        raise ValueError(f"bad unpack tiling for ({m}, {w})")
    return pl.pallas_call(
        _unpack_kernel,
        grid=(m // block_m, w // block_n),
        in_specs=[pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec(
            (block_m, block_n * pack.PACK_FACTOR), lambda i, j: (i, j)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (m, w * pack.PACK_FACTOR), jnp.int8
        ),
        interpret=INTERPRET,
    )(x)
