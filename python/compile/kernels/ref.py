"""Pure-jnp correctness oracle for the Pallas kernels.

Everything here is written with plain ``jax.numpy`` / ``lax`` ops, no Pallas,
so it is an independent implementation the kernels are validated against at
build time (pytest + hypothesis).  Integer arithmetic throughout — results
must match the Pallas kernel *exactly*, not within a tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import pack


def gemm_i32(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(M, K) int8  x  (K, N) int8  ->  (M, N) int32 accumulator."""
    return jnp.dot(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def epilogue(
    acc: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    relu: bool = True,
    requant_shift: int = 6,
) -> jnp.ndarray:
    """Post-GEMM epilogue: bias add -> ReLU -> requantize to INT4 domain.

    Mirrors the paper §3.2.2: these are the operations that must complete
    before the low-bit clip, and which the optimized kernel computes in
    registers before the shared-memory store.
    """
    out = acc + bias.astype(jnp.int32)[None, :]
    if relu:
        out = jnp.maximum(out, 0)
    return pack.requantize(out, requant_shift)


def qconv_gemm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    relu: bool = True,
    requant_shift: int = 6,
    pack_output: bool = True,
) -> jnp.ndarray:
    """Full reduced-precision GEMM pipeline: int8(int4-valued) GEMM ->
    epilogue -> optional INT4 output packing.

    Returns (M, N // 8) int32 when ``pack_output`` else (M, N) int32
    (values in [-8, 7]).
    """
    acc = gemm_i32(x, w)
    out = epilogue(acc, bias, relu=relu, requant_shift=requant_shift)
    if pack_output:
        return pack.pack_int4(out)
    return out


def im2col_nhwc(
    x: jnp.ndarray, kh: int, kw: int, stride: int = 1, padding: int = 1
) -> jnp.ndarray:
    """Lower an NHWC feature map to the im2col matrix (paper Fig. 1a /
    Fig. 3).

    x: (N, H, W, C)  ->  (N * OH * OW, KH * KW * C)

    Row r corresponds to output pixel r (row-major over N, OH, OW); its
    KH*KW*C entries are the receptive-field values, kernel-position-major —
    exactly the layout whose pixel-wise duplicates §3.1 exploits.
    """
    n, h, w_, c = x.shape
    xp = jnp.pad(
        x, ((0, 0), (padding, padding), (padding, padding), (0, 0))
    )
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w_ + 2 * padding - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            sl = xp[
                :, i : i + oh * stride : stride, j : j + ow * stride : stride, :
            ]
            patches.append(sl.reshape(n * oh * ow, c))
    return jnp.concatenate(patches, axis=1)


def conv2d_int(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    stride: int = 1,
    padding: int = 1,
) -> jnp.ndarray:
    """Direct (non-im2col) integer convolution via lax.conv — the
    independent-path oracle for the full conv pipeline.

    x: (N, H, W, C) int8, w: (KH, KW, C, O) int8 -> (N, OH, OW, O) int32
    """
    return jax.lax.conv_general_dilated(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )


def qconv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    stride: int = 1,
    padding: int = 1,
    relu: bool = True,
    requant_shift: int = 6,
    pack_output: bool = True,
) -> jnp.ndarray:
    """End-to-end quantized conv oracle: direct conv -> epilogue -> pack.

    Returns (N, OH, OW, O // 8) int32 if packed else (N, OH, OW, O) int32.
    """
    acc = conv2d_int(x, w, stride=stride, padding=padding)
    n, oh, ow, o = acc.shape
    flat = epilogue(
        acc.reshape(-1, o), bias, relu=relu, requant_shift=requant_shift
    )
    if pack_output:
        return pack.pack_int4(flat).reshape(n, oh, ow, o // pack.PACK_FACTOR)
    return flat.reshape(n, oh, ow, o)
