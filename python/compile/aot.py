"""AOT lowering: jax -> HLO *text* artifacts the rust runtime loads.

Emits HLO text, NOT ``.serialize()``: jax >= 0.5 writes HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 crate links) rejects (``proto.id() <= INT_MAX``).  The text
parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/load_hlo/ and its README.

Run once at build time (``make artifacts``):

    python -m compile.aot --out-dir ../artifacts [--batch 1]
        [--schedule-json path]   # rust-found schedule to bake in

Outputs, per ResNet50 stage conv:
    conv_<stage>.hlo.txt        the lowered quantized conv (x, w, bias) -> y
    conv_<stage>.meta.json      shapes/dtypes + schedule, for the rust loader
    golden_<stage>.bin          x||w||bias||y flat little-endian dump so the
                                rust integration tests can verify PJRT
                                numerics without python present
plus pack_demo.hlo.txt (standalone packing kernel) used by runtime tests.
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .schedules import Schedule


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (the interchange that
    survives the 0.5.1 proto-id limit)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dump_golden(path: str, arrays: list[np.ndarray]) -> None:
    """Flat binary: for each array, u32 header = byte length, then raw
    little-endian bytes.  Mirrors ``rust/src/runtime/golden.rs``."""
    with open(path, "wb") as f:
        for a in arrays:
            raw = np.ascontiguousarray(a).tobytes()
            f.write(struct.pack("<I", len(raw)))
            f.write(raw)


def pick_schedule(wl: model.ConvWorkload, schedule: Schedule) -> Schedule:
    """Shrink the requested schedule until it is legal for the workload's
    GEMM (small stages can't fit large block tiles)."""
    import dataclasses as dc

    s = schedule
    while not s.is_legal_for(wl.gemm_m, wl.gemm_n, wl.gemm_k):
        if s.chunk > 1 and wl.gemm_k % s.block_k != 0:
            s = dc.replace(s, chunk=s.chunk // 2)
        elif s.block_n > 8 and wl.gemm_n % s.block_n != 0:
            if s.warp_col_tiles > 1:
                s = dc.replace(s, warp_col_tiles=s.warp_col_tiles // 2)
            else:
                s = dc.replace(s, blk_col_warps=s.blk_col_warps // 2)
        elif s.block_m > 8 and wl.gemm_m % s.block_m != 0:
            if s.warp_row_tiles > 1:
                s = dc.replace(s, warp_row_tiles=s.warp_row_tiles // 2)
            else:
                s = dc.replace(s, blk_row_warps=s.blk_row_warps // 2)
        else:
            raise ValueError(f"cannot legalize schedule for {wl}")
    return s


def build_stage_artifacts(
    wl: model.ConvWorkload, schedule: Schedule, out_dir: str
) -> dict:
    sched = pick_schedule(wl, schedule)
    fn = model.make_stage_fn(wl, sched)
    x, w, bias = model.example_args(wl)
    lowered = jax.jit(fn).lower(x, w, bias)
    hlo = to_hlo_text(lowered)

    stage = wl.name.replace("resnet50_", "")
    hlo_path = os.path.join(out_dir, f"conv_{stage}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)

    # golden: run the *oracle* (independent path), not the kernel, so the
    # rust-side check validates kernel + AOT + PJRT all at once.
    from .kernels import ref

    y = np.asarray(model.qconv2d_fwd(x, w, bias, wl, sched))
    y_ref = np.asarray(ref.qconv2d(x, w, bias))
    assert (y == y_ref).all(), f"kernel/oracle divergence on {wl.name}"
    _dump_golden(
        os.path.join(out_dir, f"golden_{stage}.bin"),
        [np.asarray(x), np.asarray(w), np.asarray(bias), y],
    )

    meta = {
        "workload": {
            "name": wl.name,
            "batch": wl.batch,
            "height": wl.height,
            "width": wl.width,
            "in_channels": wl.in_channels,
            "out_channels": wl.out_channels,
            "kernel": wl.kernel,
            "stride": wl.stride,
            "padding": wl.padding,
            "gemm": [wl.gemm_m, wl.gemm_n, wl.gemm_k],
            "ops": wl.ops,
        },
        "schedule": json.loads(sched.to_json()),
        "inputs": [
            {"shape": list(x.shape), "dtype": "s8"},
            {"shape": list(w.shape), "dtype": "s8"},
            {"shape": list(bias.shape), "dtype": "s32"},
        ],
        "output": {"shape": list(y.shape), "dtype": "s32"},
        "hlo": os.path.basename(hlo_path),
        "golden": f"golden_{stage}.bin",
    }
    with open(os.path.join(out_dir, f"conv_{stage}.meta.json"), "w") as f:
        json.dump(meta, f, indent=2, sort_keys=True)
    return meta


def build_pack_demo(out_dir: str) -> None:
    """Standalone pack-kernel artifact (runtime smoke test target)."""
    from .kernels import conv_mma

    def fn(x):
        return (conv_mma.pack_int4_kernel(x),)

    spec = jax.ShapeDtypeStruct((16, 64), jnp.int32)
    hlo = to_hlo_text(jax.jit(fn).lower(spec))
    with open(os.path.join(out_dir, "pack_demo.hlo.txt"), "w") as f:
        f.write(hlo)
    x = (jnp.arange(16 * 64, dtype=jnp.int32).reshape(16, 64) % 23) - 11
    y = np.asarray(fn(x)[0])
    _dump_golden(
        os.path.join(out_dir, "golden_pack_demo.bin"),
        [np.asarray(x), y],
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--batch", type=int, default=8,
        help="batch baked into the artifacts (default 8, the paper's "
        "setting — also keeps every stage's GEMM M divisible by the WMMA "
        "atom: stage5 at batch 1 would have M = 49)",
    )
    ap.add_argument(
        "--schedule-json", default=None,
        help="JSON file with a rust-found Schedule to bake into the kernels",
    )
    ap.add_argument(
        "--stages", default="stage2,stage3,stage4,stage5",
        help="comma-separated stage list",
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    if args.schedule_json:
        with open(args.schedule_json) as f:
            schedule = Schedule.from_json(f.read())
    else:
        schedule = Schedule()  # default (untuned) schedule

    manifest = {"batch": args.batch, "stages": {}}
    for wl in model.resnet50_stage_convs(batch=args.batch):
        stage = wl.name.replace("resnet50_", "")
        if stage not in args.stages.split(","):
            continue
        meta = build_stage_artifacts(wl, schedule, args.out_dir)
        manifest["stages"][stage] = f"conv_{stage}.meta.json"
        print(f"lowered {wl.name}: gemm={meta['workload']['gemm']} "
              f"block=({meta['schedule']['blk_row_warps']}x"
              f"{meta['schedule']['warp_row_tiles']}x8, "
              f"{meta['schedule']['blk_col_warps']}x"
              f"{meta['schedule']['warp_col_tiles']}x8)")
    build_pack_demo(args.out_dir)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"artifacts written to {args.out_dir}")


if __name__ == "__main__":
    main()
